"""Kernel-sequence decomposition of one training/serving iteration.

This is the paper's unit of analysis: Table 1 lists the 46 kernels of one
GPT-3-xl iteration (llm.c decomposition: GEMM / Permute / Softmax /
Residual / GELU / Layernorm / Bias / embedding ± backward).  We generate the
same decomposition analytically from a :class:`ModelConfig` +
:class:`ShapeConfig` — with exact FLOPs and HBM bytes per kernel — and
extend it to every assigned architecture family (MoE dispatch, SSD scans,
cross-attention, decode GEMV/cache-read kernels) plus optional tensor/
sequence parallelism (§8; communication excluded by default, exactly as the
paper's Megatron-style extension of llm.c does) and optimizer kernels
(beyond-paper).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..configs.base import ModelConfig, ShapeConfig
from .power_model import KernelSpec


def _ceil_div(a, b):
    return -(-a // b)


def _kv_dtype_bytes(kv_dtype: Optional[str], dtype_bytes: int) -> int:
    """Stored bytes per paged-KV element.  Mirrors
    :func:`repro.serve.kv_pages.kv_dtype_bytes` without importing the
    serve layer (core must stay importable without it)."""
    if kv_dtype in (None, "none", "bf16", "fp16", "float32"):
        return dtype_bytes
    if kv_dtype in ("int8", "fp8_e4m3"):
        return 1
    raise ValueError(f"unknown kv_dtype {kv_dtype!r}")


class WorkloadBuilder:
    """Builds the ordered kernel list for one iteration of (cfg, shape)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 dtype_bytes: int = 2, tp: int = 1, sp: bool = False,
                 dp: int = 1, include_comm: bool = False,
                 include_optimizer: bool = False,
                 batch_override: Optional[int] = None,
                 kv_dtype: Optional[str] = None):
        self.cfg = cfg
        self.shape = shape
        self.db = dtype_bytes
        # bytes per *stored* paged-KV element: a quantized serve cache
        # (int8/fp8 page pools) halves the decode cache-read stream while
        # activations/weights stay at dtype_bytes — the decode roofline
        # shift the planner must see.  Dense cross-attention K/V (encdec)
        # is not paged and stays at dtype_bytes.
        self.kv_db = _kv_dtype_bytes(kv_dtype, dtype_bytes)
        self.kv_dtype = kv_dtype or "none"
        self.tp = max(tp, 1)
        self.sp = sp
        self.include_comm = include_comm
        self.include_optimizer = include_optimizer
        self.B = batch_override if batch_override is not None \
            else max(shape.global_batch // max(dp, 1), 1)
        self.S = shape.seq_len
        self.kernels: List[KernelSpec] = []

    # -- emit helpers -----------------------------------------------------
    def _emit(self, name, kind, flops, hbm, ici=0.0, inv=1, phase="fwd"):
        self.kernels.append(KernelSpec(
            name=name, kind=kind, flops=float(max(flops, 0.0)),
            hbm_bytes=float(max(hbm, 1.0)), ici_bytes=float(ici),
            invocations=int(inv), phase=phase))

    # Large GEMMs stream their input panels more than once (tiling re-reads
    # through L2); effective HBM traffic is reuse*(A+B panels) + C.
    GEMM_PANEL_REUSE = 4.0

    def _gemm(self, name, M, N, K, inv=1, phase="fwd"):
        reuse = self.GEMM_PANEL_REUSE if min(M, N, K) >= 512 else \
            (2.0 if min(M, N, K) >= 128 else 1.0)
        self._emit(name, "gemm", 2.0 * M * N * K,
                   self.db * (reuse * (M * K + K * N) + M * N),
                   inv=inv, phase=phase)

    def _gemm_bwd(self, name, M, N, K, inv=1):
        # dgrad: dX = dY @ W^T ; wgrad: dW = X^T @ dY
        self._gemm(f"{name} dgrad", M, K, N, inv=inv, phase="bwd")
        self._gemm(f"{name} wgrad", K, N, M, inv=inv, phase="bwd")

    def _elem(self, name, kind, elems, rw=3, flops_per=1.0, inv=1,
              phase="fwd"):
        self._emit(name, kind, flops_per * elems, rw * self.db * elems,
                   inv=inv, phase=phase)

    # -- family decompositions ---------------------------------------------
    def _seq_elems(self):
        """Elements of one (B, S, d) activation after sequence parallelism."""
        div = self.tp if self.sp else 1
        return self.B * self.S * self.cfg.d_model / div

    def _attention_fwd(self, prefix, S_kv=None, causal=True, inv=1,
                       d_in=None, d_out=None, window=0):
        cfg = self.cfg
        B, S, db = self.B, self.S, self.db
        d_in = d_in or cfg.d_model
        d_out = d_out or cfg.d_model
        H = max(cfg.n_heads // self.tp, 1)
        KVh = max(cfg.n_kv_heads // self.tp, 1)
        hd = cfg.resolved_head_dim or (d_in // max(cfg.n_heads, 1))
        S_kv = S_kv or S
        eff_kv = min(window, S_kv) if window else S_kv
        frac = 0.5 if (causal and not window and S == S_kv) else 1.0
        self._gemm(f"{prefix}GEMM qkv", B * S, (H + 2 * KVh) * hd, d_in,
                   inv=inv)
        if cfg.positional == "rope":
            self._elem(f"{prefix}RoPE", "permute",
                       B * S * (H + KVh) * hd, rw=2, inv=inv)
        self._elem(f"{prefix}Permute", "permute", B * S * H * hd, rw=2,
                   inv=inv)
        score_elems = B * H * S * eff_kv * frac
        panel = self.GEMM_PANEL_REUSE
        self._emit(f"{prefix}GEMM qk", "gemm", 2 * score_elems * hd,
                   db * (panel * (B * S * H * hd + B * eff_kv * KVh * hd)
                         + score_elems), inv=inv)
        self._elem(f"{prefix}Softmax", "softmax", score_elems, rw=2, inv=inv,
                   flops_per=4.0)
        self._emit(f"{prefix}GEMM av", "gemm", 2 * score_elems * hd,
                   db * (score_elems + panel * B * eff_kv * KVh * hd
                         + B * S * H * hd), inv=inv)
        self._elem(f"{prefix}Unpermute", "permute", B * S * H * hd, rw=2,
                   inv=inv)
        self._gemm(f"{prefix}GEMM proj", B * S, d_out, H * hd, inv=inv)
        if self.include_comm and self.tp > 1:
            self._emit(f"{prefix}AllReduce attn", "allreduce", 0,
                       db * B * S * d_out / 4,
                       ici=2 * db * B * S * d_out * (self.tp - 1) / self.tp,
                       inv=inv)

    def _attention_bwd(self, prefix, S_kv=None, causal=True, inv=1,
                       d_in=None, d_out=None, window=0):
        cfg = self.cfg
        B, S, db = self.B, self.S, self.db
        d_in = d_in or cfg.d_model
        d_out = d_out or cfg.d_model
        H = max(cfg.n_heads // self.tp, 1)
        KVh = max(cfg.n_kv_heads // self.tp, 1)
        hd = cfg.resolved_head_dim or (d_in // max(cfg.n_heads, 1))
        S_kv = S_kv or S
        eff_kv = min(window, S_kv) if window else S_kv
        frac = 0.5 if (causal and not window and S == S_kv) else 1.0
        score_elems = B * H * S * eff_kv * frac
        panel = self.GEMM_PANEL_REUSE
        self._gemm_bwd(f"{prefix}GEMM proj", B * S, d_out, H * hd, inv=inv)
        self._elem(f"{prefix}Permute bwd", "permute", B * S * H * hd, rw=2,
                   inv=inv, phase="bwd")
        # d(av): dP = dO V^T ; dV = P^T dO
        self._emit(f"{prefix}GEMM av dgrad", "gemm", 2 * score_elems * hd,
                   db * (panel * (B * S * H * hd + B * eff_kv * KVh * hd)
                         + score_elems), inv=inv, phase="bwd")
        self._emit(f"{prefix}GEMM av wgrad", "gemm", 2 * score_elems * hd,
                   db * (score_elems + panel * B * S * H * hd
                         + B * eff_kv * KVh * hd), inv=inv, phase="bwd")
        self._elem(f"{prefix}Softmax bwd", "softmax", score_elems, rw=3,
                   inv=inv, phase="bwd", flops_per=4.0)
        self._emit(f"{prefix}GEMM qk dgrad", "gemm", 2 * score_elems * hd,
                   db * (score_elems + panel * B * eff_kv * KVh * hd
                         + B * S * H * hd), inv=inv, phase="bwd")
        self._emit(f"{prefix}GEMM qk wgrad", "gemm", 2 * score_elems * hd,
                   db * (score_elems + panel * B * S * H * hd
                         + B * eff_kv * KVh * hd), inv=inv, phase="bwd")
        self._gemm_bwd(f"{prefix}GEMM qkv", B * S, (H + 2 * KVh) * hd, d_in,
                       inv=inv)

    def _mlp_fwd(self, prefix, inv=1, d_in=None):
        cfg = self.cfg
        B, S = self.B, self.S
        d_in = d_in or cfg.d_model
        ff = max(cfg.d_ff // self.tp, 1)
        n_up = 2 if cfg.activation == "swiglu" else 1
        self._gemm(f"{prefix}GEMM mlp up", B * S, n_up * ff, d_in, inv=inv)
        act = {"swiglu": "gelu", "gelu": "gelu", "relu2": "gelu"}
        self._elem(f"{prefix}{cfg.activation.upper()}", act[cfg.activation],
                   B * S * ff, rw=2 + (n_up - 1), inv=inv, flops_per=6.0)
        self._gemm(f"{prefix}GEMM mlp down", B * S, cfg.d_model, ff, inv=inv)
        if self.include_comm and self.tp > 1:
            self._emit(f"{prefix}AllReduce mlp", "allreduce", 0,
                       self.db * B * S * cfg.d_model / 4,
                       ici=2 * self.db * B * S * cfg.d_model
                       * (self.tp - 1) / self.tp, inv=inv)

    def _mlp_bwd(self, prefix, inv=1, d_in=None):
        cfg = self.cfg
        B, S = self.B, self.S
        d_in = d_in or cfg.d_model
        ff = max(cfg.d_ff // self.tp, 1)
        n_up = 2 if cfg.activation == "swiglu" else 1
        self._gemm_bwd(f"{prefix}GEMM mlp down", B * S, cfg.d_model, ff,
                       inv=inv)
        self._elem(f"{prefix}{cfg.activation.upper()} bwd", "gelu",
                   B * S * ff, rw=3, inv=inv, phase="bwd", flops_per=8.0)
        self._gemm_bwd(f"{prefix}GEMM mlp up", B * S, n_up * ff, d_in,
                       inv=inv)

    def _moe_fwd(self, prefix, inv=1):
        cfg = self.cfg
        B, S, db = self.B, self.S, self.db
        d = cfg.d_model
        T = B * S
        E = cfg.moe.n_experts
        K = cfg.moe.top_k
        ep = min(self.tp, E)
        ff = cfg.d_ff
        n_up = 2 if cfg.activation == "swiglu" else 1
        self._gemm(f"{prefix}GEMM router", T, E, d, inv=inv)
        self._elem(f"{prefix}Softmax+topk", "softmax", T * E, rw=2, inv=inv,
                   flops_per=6.0)
        self._elem(f"{prefix}Dispatch scatter", "dispatch", T * K * d / ep,
                   rw=2, inv=inv)
        if self.include_comm and ep > 1:
            self._emit(f"{prefix}AllToAll dispatch", "alltoall", 0,
                       db * T * K * d / ep,
                       ici=db * T * K * d * (ep - 1) / ep, inv=inv)
        Te = T * K / ep  # tokens per EP shard
        self._gemm(f"{prefix}GEMM experts up", Te, n_up * ff, d, inv=inv)
        self._elem(f"{prefix}{cfg.activation.upper()} experts", "gelu",
                   Te * ff, rw=2 + (n_up - 1), inv=inv, flops_per=6.0)
        self._gemm(f"{prefix}GEMM experts down", Te, d, ff, inv=inv)
        if self.include_comm and ep > 1:
            self._emit(f"{prefix}AllToAll combine", "alltoall", 0,
                       db * T * K * d / ep,
                       ici=db * T * K * d * (ep - 1) / ep, inv=inv)
        self._elem(f"{prefix}Combine gather", "dispatch", T * K * d / ep,
                   rw=2, inv=inv)
        if cfg.moe.shared_expert:
            self._gemm(f"{prefix}GEMM shared up", T, n_up * ff // self.tp,
                       d, inv=inv)
            self._elem(f"{prefix}Act shared", "gelu", T * ff // self.tp,
                       rw=2, inv=inv, flops_per=6.0)
            self._gemm(f"{prefix}GEMM shared down", T, d, ff // self.tp,
                       inv=inv)

    def _moe_bwd(self, prefix, inv=1):
        cfg = self.cfg
        T = self.B * self.S
        E, K = cfg.moe.n_experts, cfg.moe.top_k
        ep = min(self.tp, E)
        ff, d = cfg.d_ff, cfg.d_model
        n_up = 2 if cfg.activation == "swiglu" else 1
        Te = T * K / ep
        self._elem(f"{prefix}Combine bwd", "dispatch", T * K * d / ep, rw=2,
                   inv=inv, phase="bwd")
        self._gemm_bwd(f"{prefix}GEMM experts down", Te, d, ff, inv=inv)
        self._elem(f"{prefix}Act experts bwd", "gelu", Te * ff, rw=3,
                   inv=inv, phase="bwd", flops_per=8.0)
        self._gemm_bwd(f"{prefix}GEMM experts up", Te, n_up * ff, d, inv=inv)
        self._elem(f"{prefix}Dispatch bwd", "dispatch", T * K * d / ep, rw=2,
                   inv=inv, phase="bwd")
        self._gemm_bwd(f"{prefix}GEMM router", T, E, d, inv=inv)
        if cfg.moe.shared_expert:
            self._gemm_bwd(f"{prefix}GEMM shared down", T, d,
                           ff // self.tp, inv=inv)
            self._gemm_bwd(f"{prefix}GEMM shared up", T,
                           n_up * ff // self.tp, d, inv=inv)

    def _norm(self, name, inv=1, phase="fwd", d=None):
        elems = self.B * self.S * (d or self.cfg.d_model)
        if self.sp:
            elems /= self.tp
        self._elem(name, "layernorm", elems, rw=2 if phase == "fwd" else 4,
                   inv=inv, phase=phase, flops_per=6.0)

    def _residual(self, name, inv=1, phase="fwd"):
        elems = self._seq_elems()
        self._elem(name, "residual", elems, rw=3, inv=inv, phase=phase,
                   flops_per=1.0)

    def _ssm_fwd(self, prefix, inv=1):
        cfg = self.cfg
        s = cfg.ssm
        B, S, db = self.B, self.S, self.db
        d = cfg.d_model
        d_in = s.expand * d // self.tp
        nh = max(d_in // s.head_dim, 1)
        G, N, P = s.n_groups, s.state_dim, s.head_dim
        Q = s.chunk_size
        nc = _ceil_div(S, Q)
        conv_ch = d_in + 2 * G * N
        proj_out = 2 * d_in + 2 * G * N + nh
        self._gemm(f"{prefix}GEMM in_proj", B * S, proj_out, d, inv=inv)
        self._emit(f"{prefix}Conv1d", "conv",
                   2.0 * B * S * conv_ch * s.conv_width,
                   2 * db * B * S * conv_ch, inv=inv)
        # SSD intra-chunk dual form (CB^T, masked, @x)
        intra_flops = 2.0 * B * nc * G * Q * Q * N \
            + 2.0 * B * nc * nh * Q * Q * P
        self._emit(f"{prefix}SSD intra", "gemm", intra_flops,
                   db * (2 * B * S * G * N + B * S * nh * P
                         + B * nc * G * Q * Q), inv=inv)
        self._emit(f"{prefix}SSD state", "gemm",
                   2.0 * B * S * nh * N * P,
                   db * B * S * nh * P + 4 * B * nc * nh * N * P, inv=inv)
        self._emit(f"{prefix}SSD scan", "scan", B * nc * nh * N * P,
                   2 * 4 * B * nc * nh * N * P, inv=inv)
        self._emit(f"{prefix}SSD out", "gemm", 2.0 * B * S * nh * N * P,
                   db * (B * S * G * N + B * S * nh * P)
                   + 4 * B * nc * nh * N * P, inv=inv)
        self._elem(f"{prefix}GateNorm", "layernorm", B * S * d_in, rw=3,
                   inv=inv, flops_per=8.0)
        self._gemm(f"{prefix}GEMM out_proj", B * S, d, d_in, inv=inv)

    def _ssm_bwd(self, prefix, inv=1):
        cfg = self.cfg
        s = cfg.ssm
        B, S, db = self.B, self.S, self.db
        d = cfg.d_model
        d_in = s.expand * d // self.tp
        nh = max(d_in // s.head_dim, 1)
        G, N, P = s.n_groups, s.state_dim, s.head_dim
        Q = s.chunk_size
        nc = _ceil_div(S, Q)
        proj_out = 2 * d_in + 2 * G * N + nh
        self._gemm_bwd(f"{prefix}GEMM out_proj", B * S, d, d_in, inv=inv)
        self._elem(f"{prefix}GateNorm bwd", "layernorm", B * S * d_in, rw=4,
                   inv=inv, phase="bwd", flops_per=10.0)
        intra_flops = 2 * (2.0 * B * nc * G * Q * Q * N
                           + 2.0 * B * nc * nh * Q * Q * P)
        self._emit(f"{prefix}SSD bwd", "gemm",
                   intra_flops + 2 * 2.0 * B * S * nh * N * P,
                   2 * db * (2 * B * S * G * N + B * S * nh * P)
                   + 8 * B * nc * nh * N * P, inv=inv, phase="bwd")
        self._emit(f"{prefix}SSD scan bwd", "scan", B * nc * nh * N * P,
                   2 * 4 * B * nc * nh * N * P, inv=inv, phase="bwd")
        self._emit(f"{prefix}Conv1d bwd", "conv",
                   4.0 * B * S * (d_in + 2 * G * N) * s.conv_width,
                   4 * db * B * S * (d_in + 2 * G * N), inv=inv,
                   phase="bwd")
        self._gemm_bwd(f"{prefix}GEMM in_proj", B * S, proj_out, d, inv=inv)

    # -- loss --------------------------------------------------------------
    def _loss(self, include_bwd: bool):
        cfg = self.cfg
        B, S, db = self.B, self.S, self.db
        d = cfg.d_model
        V = max(cfg.vocab_size // self.tp, 1)
        self._norm("Layernorm final", phase="fwd")
        self._gemm("GEMM lm_head", B * S, V, d, phase="loss")
        self._elem("Softmax loss", "softmax", B * S * V, rw=2, phase="loss",
                   flops_per=5.0)
        if include_bwd:
            self._gemm("GEMM lm_head dgrad", B * S, d, V, phase="loss")
            self._gemm("GEMM lm_head wgrad", d, V, B * S, phase="loss")
            self._norm("Layernorm final bwd", phase="bwd")

    def _embedding(self, include_bwd: bool):
        cfg = self.cfg
        B, S, db = self.B, self.S, self.db
        self._emit("WTE & WPE", "embed", 0,
                   db * B * S * cfg.d_model + 4 * B * S, phase="embed")
        if include_bwd:
            if cfg.positional == "learned":
                self._emit("WPE bwd", "embed", 0,
                           db * B * S * cfg.d_model, phase="embed")
            self._emit("WTE bwd", "embed", 0,
                       2 * db * B * S * cfg.d_model, phase="embed")

    def _optimizer(self):
        total, _ = self.cfg.param_count()
        shard = total / max(self.tp, 1)
        # adamw: read p, m, v, g; write p, m, v (fp32 states)
        self._emit("AdamW update", "optimizer", 12.0 * shard,
                   4 * 7 * shard, phase="opt")

    # -- top-level families --------------------------------------------------
    def _dense_layer(self, include_bwd: bool):
        cfg = self.cfg
        L = cfg.n_layers
        if cfg.attn_window and cfg.global_attn_every:
            g = cfg.global_attn_every
            n_local = L * (g - 1) // g
            n_global = L // g
            layer_plans = [("local ", n_local, cfg.attn_window),
                           ("global ", n_global, 0)]
        else:
            layer_plans = [("", L, 0)]
        for prefix, inv, window in layer_plans:
            self._norm(f"{prefix}Layernorm attn", inv=inv)
            self._attention_fwd(prefix, inv=inv, window=window)
            self._residual(f"{prefix}Residual attn", inv=inv)
            self._norm(f"{prefix}Layernorm mlp", inv=inv)
            if cfg.is_moe:
                self._moe_fwd(prefix, inv=inv)
            else:
                self._mlp_fwd(prefix, inv=inv)
            self._residual(f"{prefix}Residual mlp", inv=inv)
        if include_bwd:
            for prefix, inv, window in layer_plans:
                self._residual(f"{prefix}Residual mlp bwd", inv=inv,
                               phase="bwd")
                if cfg.is_moe:
                    self._moe_bwd(prefix, inv=inv)
                else:
                    self._mlp_bwd(prefix, inv=inv)
                self._norm(f"{prefix}Layernorm mlp bwd", inv=inv,
                           phase="bwd")
                self._residual(f"{prefix}Residual attn bwd", inv=inv,
                               phase="bwd")
                self._attention_bwd(prefix, inv=inv, window=window)
                self._norm(f"{prefix}Layernorm attn bwd", inv=inv,
                           phase="bwd")

    def _encdec_layers(self, include_bwd: bool):
        cfg = self.cfg
        F = cfg.encoder_frontend_len
        # encoder (bidirectional, length F)
        S_save = self.S
        self.S = F
        self._norm("enc Layernorm", inv=cfg.n_encoder_layers)
        self._attention_fwd("enc ", causal=False,
                            inv=cfg.n_encoder_layers)
        self._mlp_fwd("enc ", inv=cfg.n_encoder_layers)
        self._residual("enc Residual", inv=2 * cfg.n_encoder_layers)
        if include_bwd:
            self._attention_bwd("enc ", causal=False,
                                inv=cfg.n_encoder_layers)
            self._mlp_bwd("enc ", inv=cfg.n_encoder_layers)
        self.S = S_save
        # decoder
        self._norm("dec Layernorm", inv=2 * cfg.n_layers)
        self._attention_fwd("dec self ", inv=cfg.n_layers)
        self._attention_fwd("dec cross ", S_kv=F, causal=False,
                            inv=cfg.n_layers)
        self._mlp_fwd("dec ", inv=cfg.n_layers)
        self._residual("dec Residual", inv=3 * cfg.n_layers)
        if include_bwd:
            self._attention_bwd("dec self ", inv=cfg.n_layers)
            self._attention_bwd("dec cross ", S_kv=F, causal=False,
                                inv=cfg.n_layers)
            self._mlp_bwd("dec ", inv=cfg.n_layers)

    def _ssm_layers(self, include_bwd: bool):
        cfg = self.cfg
        self._norm("Layernorm", inv=cfg.n_layers)
        self._ssm_fwd("", inv=cfg.n_layers)
        self._residual("Residual", inv=cfg.n_layers)
        if include_bwd:
            self._ssm_bwd("", inv=cfg.n_layers)

    def _hybrid_layers(self, include_bwd: bool):
        cfg = self.cfg
        n_attn = cfg.n_layers // cfg.attn_every \
            + (1 if cfg.n_layers % cfg.attn_every else 0)
        self._norm("Layernorm", inv=cfg.n_layers)
        self._ssm_fwd("", inv=cfg.n_layers)
        self._residual("Residual", inv=cfg.n_layers)
        d2 = 2 * cfg.d_model
        self._norm("shared Layernorm", inv=2 * n_attn, d=d2)
        self._attention_fwd("shared ", inv=n_attn, d_in=d2)
        self._mlp_fwd("shared ", inv=n_attn, d_in=d2)
        if include_bwd:
            self._ssm_bwd("", inv=cfg.n_layers)
            self._attention_bwd("shared ", inv=n_attn, d_in=d2)
            self._mlp_bwd("shared ", inv=n_attn, d_in=d2)

    # -- decode ---------------------------------------------------------------
    def _decode_kernels(self):
        """One decode step: GEMV projections + cache-read attention."""
        cfg = self.cfg
        B, S, db = self.B, self.S, self.db
        d = cfg.d_model

        def gemv(name, N, K, inv=1):
            # M = B: weight-read dominated
            self._gemm(name, B, N, K, inv=inv)

        if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            if cfg.family == "hybrid":
                n_attn = cfg.n_layers // cfg.attn_every \
                    + (1 if cfg.n_layers % cfg.attn_every else 0)
                att_inv, d_in = n_attn, 2 * d
            elif cfg.family == "encdec":
                att_inv, d_in = cfg.n_layers, d
            else:
                att_inv, d_in = cfg.n_layers, d
            H = max(cfg.n_heads // self.tp, 1)
            KVh = max(cfg.n_kv_heads // self.tp, 1)
            hd = cfg.resolved_head_dim or (d_in // max(cfg.n_heads, 1))
            if cfg.attn_window and cfg.global_attn_every:
                g = cfg.global_attn_every
                plans = [("local ", att_inv * (g - 1) // g,
                          min(cfg.attn_window, S)),
                         ("global ", att_inv // g, S)]
            else:
                plans = [("", att_inv, S)]
            for prefix, inv, S_eff in plans:
                gemv(f"{prefix}GEMV qkv", (H + 2 * KVh) * hd, d_in, inv=inv)
                # cache-read attention: streams the whole KV cache at its
                # *stored* width (kv_db < db under a quantized page pool —
                # the kernel's arithmetic intensity rises accordingly;
                # per-page scale reads are < 0.5% of payload and elided)
                self._emit(f"{prefix}Attn cache read", "attn_decode",
                           4.0 * B * H * S_eff * hd,
                           self.kv_db * 2 * B * S_eff * KVh * hd, inv=inv)
                gemv(f"{prefix}GEMV attn proj", d, H * hd, inv=inv)
            if cfg.family == "encdec":
                F = cfg.encoder_frontend_len
                gemv("GEMV cross q", H * hd, d, inv=cfg.n_layers)
                self._emit("Cross cache read", "attn_decode",
                           4.0 * B * H * F * hd,
                           db * 2 * B * F * KVh * hd, inv=cfg.n_layers)
                gemv("GEMV cross proj", d, H * hd, inv=cfg.n_layers)
        if cfg.family in ("ssm", "hybrid"):
            s = cfg.ssm
            d_in = s.expand * d // self.tp
            nh = max(d_in // s.head_dim, 1)
            N, P = s.state_dim, s.head_dim
            proj_out = 2 * d_in + 2 * s.n_groups * N + nh
            gemv("GEMV in_proj", proj_out, d, inv=cfg.n_layers)
            self._emit("SSM state update", "scan", 4.0 * B * nh * N * P,
                       2 * 4 * B * nh * N * P, inv=cfg.n_layers)
            gemv("GEMV out_proj", d, d_in, inv=cfg.n_layers)
        if cfg.family in ("dense", "vlm") or cfg.is_moe:
            ff = max(cfg.d_ff // self.tp, 1)
            n_up = 2 if cfg.activation == "swiglu" else 1
            if cfg.is_moe:
                K = cfg.moe.top_k
                gemv("GEMV router", cfg.moe.n_experts, d, inv=cfg.n_layers)
                gemv("GEMV experts", K * (n_up + 1) * ff, d,
                     inv=cfg.n_layers)
                if cfg.moe.shared_expert:
                    gemv("GEMV shared", (n_up + 1) * ff, d, inv=cfg.n_layers)
            else:
                gemv("GEMV mlp", (n_up + 1) * ff, d, inv=cfg.n_layers)
        elif cfg.family in ("encdec", "hybrid") and cfg.d_ff:
            ff = max(cfg.d_ff // self.tp, 1)
            n_up = 2 if cfg.activation == "swiglu" else 1
            inv = cfg.n_layers if cfg.family == "encdec" else \
                cfg.n_layers // cfg.attn_every + 1
            gemv("GEMV mlp", (n_up + 1) * ff, 2 * d
                 if cfg.family == "hybrid" else d, inv=inv)
        # norms + unembed
        self._elem("Norms decode", "layernorm", B * d * 2 * cfg.n_layers,
                   rw=2, flops_per=6.0)
        gemv("GEMV lm_head", max(cfg.vocab_size // self.tp, 1), d)

    # -- entry point ------------------------------------------------------
    def build(self) -> List[KernelSpec]:
        self.kernels = []
        cfg, shape = self.cfg, self.shape
        if shape.kind == "decode":
            self._decode_kernels()
            return self.kernels
        include_bwd = shape.kind == "train"
        self._embedding(include_bwd)
        if cfg.family in ("dense", "moe", "vlm"):
            self._dense_layer(include_bwd)
        elif cfg.family == "encdec":
            self._encdec_layers(include_bwd)
        elif cfg.family == "ssm":
            self._ssm_layers(include_bwd)
        elif cfg.family == "hybrid":
            self._hybrid_layers(include_bwd)
        self._loss(include_bwd)
        if include_bwd and self.include_optimizer:
            self._optimizer()
        return self.kernels


def build_workload(cfg: ModelConfig, shape: ShapeConfig,
                   **kw) -> List[KernelSpec]:
    return WorkloadBuilder(cfg, shape, **kw).build()


def decode_slot_buckets(n_slots: int) -> List[int]:
    """Active-slot-count buckets for continuous-batching decode plans.

    Powers of two up to (and always including) ``n_slots``: a decode step
    with ``a`` active slots replays the plan of the smallest bucket
    >= ``a``, so a pool of S slots needs only O(log S) plans instead of S.
    """
    if n_slots < 1:
        raise ValueError(f"n_slots must be >= 1, got {n_slots}")
    buckets = [1]
    while buckets[-1] < n_slots:
        buckets.append(min(2 * buckets[-1], n_slots))
    return buckets


def pick_decode_bucket(buckets: List[int], n_active: int) -> int:
    """THE bucket-routing rule: smallest bucket >= n_active (largest if
    none).  Single-sourced so the plan IR and the legacy bundle shim can
    never route decode steps differently."""
    if not buckets:
        raise KeyError("no decode buckets to route to")
    for b in buckets:
        if b >= n_active:
            return b
    return buckets[-1]


def decode_bucket_workloads(cfg: ModelConfig, shape: ShapeConfig,
                            n_slots: int, **kw
                            ) -> "Dict[int, List[KernelSpec]]":
    """One decode-step kernel list per active-slot bucket.

    ``shape`` must be a decode shape; its ``global_batch`` is overridden
    with each bucket size (the decode workload scales with the number of
    sequences actually resident in the batch).
    """
    if shape.kind != "decode":
        raise ValueError(f"decode shape required, got kind={shape.kind!r}")
    return {b: WorkloadBuilder(cfg, shape, batch_override=b, **kw).build()
            for b in decode_slot_buckets(n_slots)}


def workload_totals(kernels: List[KernelSpec]):
    """Aggregate (flops, hbm_bytes, ici_bytes) over invocations."""
    f = sum(k.flops * k.invocations for k in kernels)
    h = sum(k.hbm_bytes * k.invocations for k in kernels)
    i = sum(k.ici_bytes * k.invocations for k in kernels)
    return f, h, i
