"""Frequency planners: pass-level vs kernel-level, local vs global.

The paper's comparison matrix (Table 2):

* granularity — coarse (one clock pair per *pass*) vs fine (per *kernel*);
* aggregation — local optima (every unit obeys the time constraint on its
  own) vs global optimum (only the *total* time is constrained; kernels
  cooperatively trade slack — found with a constraint solver in the paper).

The global problem is a multiple-choice knapsack:

    min Σ_k w_k · e[k, c_k]   s.t.   Σ_k w_k · t[k, c_k] ≤ (1+τ)·T_auto .

We solve it with Lagrangian relaxation (binary search on λ, optimal up to
the duality gap on the discrete frontier) followed by a greedy slack
refill, and provide an exact discretized DP for cross-validation.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .freq import ClockPair
from .measure import MeasurementTable
from .objectives import WastePolicy, pct


@dataclass
class Plan:
    """A per-kernel clock assignment plus expected outcome."""

    name: str
    choice: np.ndarray                  # (n_kernels,) pair index
    table: MeasurementTable
    time_s: float = 0.0
    energy_j: float = 0.0
    base_time_s: float = 0.0
    base_energy_j: float = 0.0

    def __post_init__(self):
        self.time_s, self.energy_j = self.table.totals(self.choice)
        self.base_time_s, self.base_energy_j = self.table.baseline_totals()

    @property
    def time_pct(self) -> float:
        return pct(self.time_s, self.base_time_s)

    @property
    def energy_pct(self) -> float:
        return pct(self.energy_j, self.base_energy_j)

    def summary(self) -> Dict:
        return {"plan": self.name,
                "time_pct": round(self.time_pct, 3),
                "energy_pct": round(self.energy_pct, 3),
                "time_s": self.time_s, "energy_j": self.energy_j,
                "base_time_s": self.base_time_s,
                "base_energy_j": self.base_energy_j}

    def per_kernel(self) -> List[Dict]:
        rows = []
        t = self.table
        for i, k in enumerate(t.kernels):
            c = int(self.choice[i])
            rows.append({
                "kernel": k.name, "kind": k.kind,
                "invocations": k.invocations,
                "mem": t.pairs[c].mem, "core": t.pairs[c].core,
                "time_pct": pct(t.time[i, c], t.time[i, t.auto_idx]),
                "energy_pct": pct(t.energy[i, c],
                                  t.energy[i, t.auto_idx]),
            })
        return rows


# ---------------------------------------------------------------------------
# Kernel-level planners
# ---------------------------------------------------------------------------

def local_plan(table: MeasurementTable, policy: Optional[WastePolicy] = None
               ) -> Plan:
    """Every kernel independently obeys t_k <= (1+tau) * t_k(auto)."""
    policy = policy if policy is not None else WastePolicy()
    n, _ = table.time.shape
    choice = np.full(n, table.auto_idx)
    for k in range(n):
        budget = (1.0 + policy.tau) * table.time[k, table.auto_idx]
        feas = table.time[k] <= budget * (1 + 1e-12)
        if feas.any():
            e = np.where(feas, table.energy[k], np.inf)
            choice[k] = int(np.argmin(e))
    return Plan("kernel-local", choice, table)


def _lagrangian_choice(table: MeasurementTable, lam: float) -> np.ndarray:
    score = table.energy + lam * table.time
    return np.argmin(score, axis=1)


def global_plan(table: MeasurementTable, policy: Optional[WastePolicy] = None,
                refine: bool = True) -> Plan:
    """Global optimum: only the total time is constrained (paper's
    constraint-solver aggregation), via Lagrangian relaxation + greedy
    slack refill."""
    policy = policy if policy is not None else WastePolicy()
    t_base, _ = table.baseline_totals()
    budget = policy.budget(t_base)

    choice = _lagrangian_choice(table, 0.0)
    t_tot, _ = table.totals(choice)
    if t_tot > budget:
        lo, hi = 0.0, 1.0
        while True:  # find upper bracket
            choice = _lagrangian_choice(table, hi)
            t_tot, _ = table.totals(choice)
            if t_tot <= budget or hi > 1e18:
                break
            hi *= 8.0
        for _ in range(80):
            mid = 0.5 * (lo + hi)
            choice = _lagrangian_choice(table, mid)
            t_tot, _ = table.totals(choice)
            if t_tot <= budget:
                hi = mid
            else:
                lo = mid
        choice = _lagrangian_choice(table, hi)

    if refine:
        choice = _greedy_refill(table, choice, budget)
    return Plan("kernel-global", choice, table)


def _greedy_refill(table: MeasurementTable, choice: np.ndarray,
                   budget: float) -> np.ndarray:
    """Spend leftover time slack on the best remaining ΔE/Δt swaps.

    The running total and the per-kernel (Δt, ΔE) rows are maintained
    incrementally: a swap only touches kernel ``k``, so only row ``k`` of
    the delta matrices (and the scalar totals) change — O(n_pairs) per
    swap instead of the former O(n·n_pairs) ``table.totals`` recompute.
    """
    choice = choice.copy()
    w = table.weights
    idx = np.arange(len(table.kernels))
    Tw = table.time * w[:, None]                   # (n, pairs) weighted
    Ew = table.energy * w[:, None]
    t_tot = float(Tw[idx, choice].sum())
    dt = Tw - Tw[idx, choice][:, None]             # delta vs current choice
    de = Ew - Ew[idx, choice][:, None]
    for _ in range(4 * len(choice)):
        slack = budget - t_tot
        # candidates: save energy, fit in slack
        ok = (de < -1e-15) & (dt <= slack + 1e-15)
        if not ok.any():
            break
        ratio = np.where(ok, de / np.maximum(dt, 1e-12), np.inf)
        # prefer swaps that save energy per unit time spent; free swaps
        # (dt<=0, de<0) first
        free = ok & (dt <= 0)
        if free.any():
            gain = np.where(free, de, 0.0)
            k, c = np.unravel_index(np.argmin(gain), gain.shape)
        else:
            k, c = np.unravel_index(np.argmin(ratio), ratio.shape)
        if choice[k] == c:
            break
        t_tot += dt[k, c]
        choice[k] = c
        dt[k] = Tw[k] - Tw[k, c]
        de[k] = Ew[k] - Ew[k, c]
    return choice


def global_plan_dp(table: MeasurementTable,
                   policy: Optional[WastePolicy] = None,
                   n_bins: int = 2000) -> Plan:
    """Exact (discretized) multiple-choice knapsack DP, for validation."""
    policy = policy if policy is not None else WastePolicy()
    t_base, _ = table.baseline_totals()
    budget = policy.budget(t_base)
    w = table.weights
    T = table.time * w[:, None]
    E = table.energy * w[:, None]
    scale = n_bins / budget
    Tq = np.ceil(T * scale).astype(int)
    best = np.full(n_bins + 1, np.inf)
    best[0] = 0.0
    parent: List[np.ndarray] = []
    for k in range(len(table.kernels)):
        new = np.full(n_bins + 1, np.inf)
        arg = np.full(n_bins + 1, -1)
        for c in range(T.shape[1]):
            tq = Tq[k, c]
            if tq > n_bins:
                continue
            cand = np.full(n_bins + 1, np.inf)
            cand[tq:] = best[:n_bins + 1 - tq] + E[k, c]
            upd = cand < new
            new[upd] = cand[upd]
            arg[upd] = c
        parent.append(arg)
        best = new
    end = int(np.argmin(best))
    if not np.isfinite(best[end]):
        return Plan("kernel-global-dp",
                    np.full(len(table.kernels), table.auto_idx), table)
    choice = np.zeros(len(table.kernels), dtype=int)
    b = end
    for k in range(len(table.kernels) - 1, -1, -1):
        c = int(parent[k][b])
        choice[k] = c
        b -= Tq[k, c]
    return Plan("kernel-global-dp", choice, table)


# ---------------------------------------------------------------------------
# Pass-level (coarse-grained) planners
# ---------------------------------------------------------------------------

PASS_GROUPS = ("embed", "fwd", "loss", "bwd", "opt")


def _pass_tables(table: MeasurementTable) -> Dict[str, np.ndarray]:
    """Aggregate the kernel grid into per-pass (time, energy) rows."""
    phases = np.array([k.phase for k in table.kernels])
    w = table.weights[:, None]
    out = {}
    for ph in PASS_GROUPS:
        m = phases == ph
        if m.any():
            out[ph] = (np.sum(table.time[m] * w[m], axis=0),
                       np.sum(table.energy[m] * w[m], axis=0))
    return out


def pass_level_plan(table: MeasurementTable,
                    policy: Optional[WastePolicy] = None,
                    aggregation: str = "global") -> Plan:
    """One clock pair per pass (the paper's §5 coarse baseline)."""
    policy = policy if policy is not None else WastePolicy()
    groups = _pass_tables(table)
    names = list(groups)
    Tm = np.stack([groups[g][0] for g in names])   # (n_pass, n_pairs)
    Em = np.stack([groups[g][1] for g in names])
    auto = table.auto_idx
    if aggregation == "local":
        sel = {}
        for gi, g in enumerate(names):
            budget = (1.0 + policy.tau) * Tm[gi, auto]
            feas = Tm[gi] <= budget * (1 + 1e-12)
            e = np.where(feas, Em[gi], np.inf)
            sel[g] = int(np.argmin(e)) if feas.any() else auto
    else:
        # global over passes: tiny multiple-choice knapsack, solved exactly
        # by Lagrangian + refill on a pass-level pseudo-table
        pseudo = MeasurementTable(
            chip_name=table.chip_name,
            kernels=[dataclasses.replace(table.kernels[0], name=g,
                                         invocations=1) for g in names],
            pairs=table.pairs, time=Tm, energy=Em, auto_idx=auto)
        p = global_plan(pseudo, policy)
        sel = {g: int(p.choice[gi]) for gi, g in enumerate(names)}
    choice = np.array([sel.get(k.phase, auto) for k in table.kernels])
    return Plan(f"pass-{aggregation}", choice, table)


# ---------------------------------------------------------------------------
# EDP planners (prior-work objective, for Table 2)
# ---------------------------------------------------------------------------

def edp_local_plan(table: MeasurementTable) -> Plan:
    """Per-kernel argmin of t*e."""
    choice = np.argmin(table.time * table.energy, axis=1)
    return Plan("edp-local", choice, table)


def edp_global_plan(table: MeasurementTable, n_lambda: int = 200) -> Plan:
    """Global EDP: min (Σt)(Σe).  Sweep the Lagrangian frontier (all
    Pareto-optimal (T,E) aggregates) and pick the min-product point."""
    lams = np.concatenate([[0.0], np.logspace(-6, 18, n_lambda)])
    best = None
    for lam in lams:
        choice = _lagrangian_choice(table, lam)
        t, e = table.totals(choice)
        if best is None or t * e < best[0]:
            best = (t * e, choice)
    return Plan("edp-global", best[1], table)


def edp_pass_plan(table: MeasurementTable) -> Plan:
    """Coarse-grained EDP (per-pass argmin of pass-aggregated t*e)."""
    groups = _pass_tables(table)
    sel = {g: int(np.argmin(groups[g][0] * groups[g][1])) for g in groups}
    choice = np.array([sel.get(k.phase, table.auto_idx)
                       for k in table.kernels])
    return Plan("edp-pass", choice, table)
