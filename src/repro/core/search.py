"""Measurement-efficient frequency search (beyond-paper).

The paper's exhaustive campaign costs ~3 GPU-days (§4) and it argues an
*efficient* search that still optimizes **globally** "will be more complex
and will require a larger search space" (§6).  This module provides one:

1. **Boundedness-guided pruning** — each kernel's arithmetic intensity
   (known statically from the workload model) predicts which clock domain
   has headroom; compute-bound kernels only sweep memory clocks near the
   roofline-feasible range and vice versa.
2. **Successive halving** over the surviving (kernel, pair) cells: all
   cells get one cheap (noisy) measurement; the best half per kernel is
   re-measured with doubled repetitions, etc.  Measurement *cost* is
   counted in repetition-units, the currency of the paper's 5-second
   windows.
3. The surviving grid feeds the ordinary global (Lagrangian) planner, so
   the search stays globally-aggregated — the property the paper says is
   hard to keep.

``search_plan`` returns (plan, cost_report); `benchmarks/search_cost.py`
compares it against the exhaustive campaign.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .freq import AUTO, ClockPair
from .measure import Campaign, MeasurementTable, NoiseModel
from .objectives import WastePolicy
from .planner import Plan, global_plan
from .power_model import Chip, KernelSpec


@dataclass
class SearchReport:
    measurements: int            # repetition-units spent
    exhaustive_measurements: int
    cells_swept: int
    cells_total: int

    @property
    def cost_fraction(self) -> float:
        return self.measurements / max(self.exhaustive_measurements, 1)


def _candidate_mask(chip: Chip, kernels: Sequence[KernelSpec],
                    pairs: Sequence[ClockPair]) -> np.ndarray:
    """(n_kernels, n_pairs) bool: cells worth measuring.

    Static pruning from the roofline: for a kernel bound on domain D at
    full clocks, lowering D's clock below its utilization ratio is a
    guaranteed slowdown — prune those cells; the *other* domain sweeps
    freely.  The auto pair is always kept (it is the baseline).
    """
    n_k, n_p = len(kernels), len(pairs)
    mask = np.zeros((n_k, n_p), dtype=bool)
    fmax_c = chip.grid.core_clocks_mhz[-1]
    fmax_m = chip.grid.mem_clocks_mhz[-1]
    for i, k in enumerate(kernels):
        t_c = k.flops / chip.peak_flops
        t_m = k.hbm_bytes / chip.hbm_bw
        bound = max(t_c, t_m, 1e-30)
        # headroom ratios: how far each domain's clock can drop before it
        # becomes the bottleneck (plus one grid step of margin)
        r_core = t_c / bound
        r_mem = t_m / bound
        for j, p in enumerate(pairs):
            if p.is_auto:
                mask[i, j] = True
                continue
            fc = 1.0 if p.core == AUTO else p.core / fmax_c
            fm = 1.0 if p.mem == AUTO else p.mem / fmax_m
            # keep a cell if neither clock dips far below its domain's
            # feasibility ratio (x0.7 margin: the global planner may
            # still buy small slowdowns)
            if fc >= 0.7 * r_core and fm >= 0.7 * r_mem * 0.5:
                # (mem has the bw-efficiency knee at 0.5: anything below
                # half clock is never useful — §5's 405/810 finding)
                if p.mem == AUTO or fm >= 0.45:
                    mask[i, j] = True
    return mask


def search_plan(chip: Chip, kernels: Sequence[KernelSpec],
                policy: Optional[WastePolicy] = None,
                rounds: int = 3, base_reps: int = 1, keep_frac: float = 0.5,
                seed: int = 0,
                noise: Optional[NoiseModel] = None
                ) -> Tuple[Plan, SearchReport]:
    """Boundedness-pruned successive-halving search + global planning."""
    policy = policy if policy is not None else WastePolicy()
    pairs = chip.grid.pairs()
    n_k, n_p = len(kernels), len(pairs)
    camp = Campaign(chip, seed=seed, n_reps=1, noise=noise)
    truth_t, truth_e = chip.evaluate_grid(kernels, pairs)

    mask = _candidate_mask(chip, kernels, pairs)
    auto_idx = pairs.index(ClockPair(AUTO, AUTO))

    rng = np.random.default_rng(seed)
    nm = noise or NoiseModel()
    est_t = np.full((n_k, n_p), np.inf)
    est_e = np.full((n_k, n_p), np.inf)
    reps_done = np.zeros((n_k, n_p), dtype=int)
    alive = mask.copy()
    measurements = 0
    reps = base_reps
    for rnd in range(rounds):
        # measure every live cell `reps` more times (averaging down noise)
        idx = np.where(alive)
        n_cells = len(idx[0])
        for _ in range(reps):
            tn, en = nm.sample(rng, truth_t, truth_e)
            for i, j in zip(*idx):
                prev = reps_done[i, j]
                if prev == 0:
                    est_t[i, j], est_e[i, j] = tn[i, j], en[i, j]
                else:
                    est_t[i, j] = (est_t[i, j] * prev + tn[i, j]) / (prev + 1)
                    est_e[i, j] = (est_e[i, j] * prev + en[i, j]) / (prev + 1)
                reps_done[i, j] = prev + 1
        measurements += n_cells * reps
        if rnd == rounds - 1:
            break
        # keep the most promising half per kernel: rank by energy among
        # cells that are not grossly slower than auto
        for i in range(n_k):
            live_j = np.where(alive[i])[0]
            if len(live_j) <= 2:
                continue
            t_auto = est_t[i, auto_idx]
            score = np.where(est_t[i, live_j] <= 1.3 * t_auto,
                             est_e[i, live_j], np.inf)
            order = live_j[np.argsort(score)]
            n_keep = max(int(np.ceil(len(live_j) * keep_frac)), 2)
            drop = order[n_keep:]
            alive[i, drop] = False
            alive[i, auto_idx] = True
        reps *= 2

    # unswept cells: fill with pessimistic values so the planner never
    # picks them
    t_fill = np.where(reps_done > 0, est_t, 1e12)
    e_fill = np.where(reps_done > 0, est_e, 1e12)
    t_fill[:, auto_idx] = est_t[:, auto_idx]
    e_fill[:, auto_idx] = est_e[:, auto_idx]
    table = MeasurementTable(chip_name=chip.name, kernels=list(kernels),
                             pairs=pairs, time=t_fill, energy=e_fill,
                             auto_idx=auto_idx)
    plan = global_plan(table, policy)
    report = SearchReport(
        measurements=measurements,
        exhaustive_measurements=n_k * n_p * (base_reps * (2 ** rounds - 1)),
        cells_swept=int(mask.sum()), cells_total=n_k * n_p)
    return plan, report


def evaluate_against_truth(chip: Chip, kernels, plan: Plan):
    """True (noise-free) totals of a plan vs the auto baseline."""
    pairs = plan.table.pairs
    T, E = chip.evaluate_grid(kernels, pairs)
    w = np.array([k.invocations for k in kernels], float)
    idx = np.arange(len(kernels))
    t = float((w * T[idx, plan.choice]).sum())
    e = float((w * E[idx, plan.choice]).sum())
    tb = float((w * T[:, plan.table.auto_idx]).sum())
    eb = float((w * E[:, plan.table.auto_idx]).sum())
    return 100 * (t / tb - 1), 100 * (e / eb - 1)
