"""Switch-latency-aware plan smoothing (beyond-paper).

The paper (§9) notes that real clock switches cost 1 µs – 100 ms depending
on hardware generation, so not every per-kernel clock change is realizable.
We make switch cost a first-class term: given the *execution-ordered*
kernel-instance sequence, choose clocks minimizing energy subject to the
global time budget *including* switch latencies, via a Lagrangian DP with
transition costs:

    dp_i(c) = w_i·(e[i,c] + λ·t[i,c]) + min( dp_{i-1}(c),
                                             min_{c'} dp_{i-1}(c') + λ·L_s + E_s )

This collapses to the paper's global plan when L_s → 0 and to the auto
baseline when L_s is large (the paper's observation that high switching
latencies "worsen the DVFS potential").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .measure import MeasurementTable
from .objectives import WastePolicy, pct
from .planner import Plan

# modeled power draw during a clock switch (paper §9 ballpark); every
# accounting site (planner, meter, executor, transfer) must share it
SWITCH_POWER_W = 100.0


def expand_sequence(table: MeasurementTable) -> np.ndarray:
    """Approximate execution order of kernel instances.

    Kernels are emitted by the workload builder in per-layer order with an
    ``invocations`` multiplier; execution interleaves them per layer.  We
    expand phase-by-phase: within a phase, kernels repeat round-robin
    according to their invocation counts (kernel with inv=L contributes one
    instance per layer-pass)."""
    order: List[int] = []
    phases: List[str] = []
    for k in table.kernels:
        if k.phase not in phases:
            phases.append(k.phase)
    for ph in phases:
        idxs = [i for i, k in enumerate(table.kernels) if k.phase == ph]
        max_inv = max(table.kernels[i].invocations for i in idxs)
        for rep in range(max_inv):
            for i in idxs:
                inv = table.kernels[i].invocations
                # spread inv instances uniformly over max_inv slots
                if (rep * inv) // max_inv != ((rep + 1) * inv) // max_inv:
                    order.append(i)
    return np.asarray(order, dtype=int)


@dataclass
class CoalescedPlan:
    """Per-instance clock schedule with switch accounting."""

    choice_seq: np.ndarray         # (n_instances,) pair index
    sequence: np.ndarray           # (n_instances,) kernel index
    table: MeasurementTable
    switch_latency_s: float
    switch_energy_j: float
    time_s: float = 0.0
    energy_j: float = 0.0
    n_switches: int = 0
    base_time_s: float = 0.0
    base_energy_j: float = 0.0

    def __post_init__(self):
        t = self.table
        tt = float(t.time[self.sequence, self.choice_seq].sum())
        ee = float(t.energy[self.sequence, self.choice_seq].sum())
        sw = int(np.sum(self.choice_seq[1:] != self.choice_seq[:-1]))
        self.n_switches = sw
        self.time_s = tt + sw * self.switch_latency_s
        self.energy_j = ee + sw * self.switch_energy_j
        self.base_time_s = float(t.time[self.sequence, t.auto_idx].sum())
        self.base_energy_j = float(t.energy[self.sequence, t.auto_idx].sum())

    @property
    def time_pct(self):
        return pct(self.time_s, self.base_time_s)

    @property
    def energy_pct(self):
        return pct(self.energy_j, self.base_energy_j)

    def summary(self) -> Dict:
        return {"plan": "coalesced-global",
                "switch_latency_s": self.switch_latency_s,
                "n_instances": len(self.sequence),
                "n_switches": self.n_switches,
                "time_pct": round(self.time_pct, 3),
                "energy_pct": round(self.energy_pct, 3),
                "time_s": self.time_s, "energy_j": self.energy_j,
                "base_time_s": self.base_time_s,
                "base_energy_j": self.base_energy_j}


def _dp_for_lambda(T: np.ndarray, E: np.ndarray, lam: float,
                   switch_t: float, switch_e: float) -> np.ndarray:
    """Vectorized DP; returns per-instance choices (n, ) given λ."""
    n, C = T.shape
    cost = E + lam * T                     # (n, C)
    pen = switch_e + lam * switch_t
    dp = cost[0].copy()
    parent = np.zeros((n, C), dtype=np.int32)
    parent[0] = np.arange(C)
    for i in range(1, n):
        best_prev = int(np.argmin(dp))
        stay = dp                           # same clock as previous
        move = dp[best_prev] + pen          # switch from the best prev
        use_stay = stay <= move
        base = np.where(use_stay, stay, move)
        parent[i] = np.where(use_stay, np.arange(C), best_prev)
        dp = base + cost[i]
    choice = np.zeros(n, dtype=np.int32)
    choice[-1] = int(np.argmin(dp))
    for i in range(n - 1, 0, -1):
        choice[i - 1] = parent[i][choice[i]]
    return choice


def coalesced_global_plan(table: MeasurementTable,
                          policy: WastePolicy = WastePolicy(),
                          switch_latency_s: Optional[float] = None,
                          switch_power_w: float = SWITCH_POWER_W,
                          sequence: Optional[np.ndarray] = None
                          ) -> CoalescedPlan:
    """Energy-min plan under the time budget *including* switch costs."""
    seq = expand_sequence(table) if sequence is None else sequence
    T = table.time[seq]
    E = table.energy[seq]
    sl = switch_latency_s if switch_latency_s is not None else 1e-6
    se = switch_power_w * sl
    t_base = float(table.time[seq, table.auto_idx].sum())
    budget = policy.budget(t_base)

    def solve(lam):
        ch = _dp_for_lambda(T, E, lam, sl, se)
        sw = int(np.sum(ch[1:] != ch[:-1]))
        t = float(T[np.arange(len(seq)), ch].sum()) + sw * sl
        return ch, t

    ch, t = solve(0.0)
    if t > budget:
        lo, hi = 0.0, 1.0
        while True:
            ch, t = solve(hi)
            if t <= budget or hi > 1e18:
                break
            hi *= 8.0
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            ch, t = solve(mid)
            if t <= budget:
                hi = mid
            else:
                lo = mid
        ch, t = solve(hi)
    if t > budget:  # infeasible even at huge λ -> stay on auto
        ch = np.full(len(seq), table.auto_idx, dtype=np.int32)
    return CoalescedPlan(choice_seq=ch, sequence=seq, table=table,
                         switch_latency_s=sl, switch_energy_j=se)
