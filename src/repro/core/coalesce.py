"""Switch-latency-aware plan smoothing (beyond-paper).

The paper (§9) notes that real clock switches cost 1 µs – 100 ms depending
on hardware generation, so not every per-kernel clock change is realizable.
We make switch cost a first-class term: given the *execution-ordered*
kernel-instance sequence, choose clocks minimizing energy subject to the
global time budget *including* switch latencies, via a Lagrangian DP with
transition costs:

    dp_i(c) = w_i·(e[i,c] + λ·t[i,c]) + min( dp_{i-1}(c),
                                             min_{c'} dp_{i-1}(c') + λ·L_s + E_s )

This collapses to the paper's global plan when L_s → 0 and to the auto
baseline when L_s is large (the paper's observation that high switching
latencies "worsen the DVFS potential").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .measure import MeasurementTable
from .objectives import WastePolicy, pct
from .planner import Plan

# modeled power draw during a clock switch (paper §9 ballpark); every
# accounting site (planner, meter, executor, transfer) must share it
SWITCH_POWER_W = 100.0


def expand_sequence(table: MeasurementTable) -> np.ndarray:
    """Approximate execution order of kernel instances.

    Kernels are emitted by the workload builder in per-layer order with an
    ``invocations`` multiplier; execution interleaves them per layer.  We
    expand phase-by-phase: within a phase, kernels repeat round-robin
    according to their invocation counts (kernel with inv=L contributes one
    instance per layer-pass).

    Fully vectorized: one boolean ``(max_inv, n_phase_kernels)`` occupancy
    grid per phase, flattened in (rep, kernel) order — a 10k-instance
    campaign expands in microseconds instead of a Python double loop.
    """
    phases_arr = np.array([k.phase for k in table.kernels])
    inv_arr = np.array([k.invocations for k in table.kernels], dtype=np.int64)
    order: List[np.ndarray] = []
    # np.unique sorts; preserve first-appearance phase order instead
    seen: Dict[str, None] = {}
    for p in phases_arr:
        seen.setdefault(p, None)
    for ph in seen:
        idxs = np.nonzero(phases_arr == ph)[0]
        inv = inv_arr[idxs]                       # (K,)
        max_inv = int(inv.max())
        reps = np.arange(max_inv, dtype=np.int64)[:, None]       # (R, 1)
        # kernel i occupies rep slot r iff the uniform spread of its inv
        # instances over max_inv slots crosses an integer boundary at r
        take = (reps * inv) // max_inv != ((reps + 1) * inv) // max_inv
        grid = np.broadcast_to(idxs, take.shape)  # (R, K)
        order.append(grid[take])                  # row-major == (rep, kernel)
    return np.concatenate(order).astype(int) if order \
        else np.zeros(0, dtype=int)


@dataclass
class CoalescedPlan:
    """Per-instance clock schedule with switch accounting."""

    choice_seq: np.ndarray         # (n_instances,) pair index
    sequence: np.ndarray           # (n_instances,) kernel index
    table: MeasurementTable
    switch_latency_s: float
    switch_energy_j: float
    time_s: float = 0.0
    energy_j: float = 0.0
    n_switches: int = 0
    base_time_s: float = 0.0
    base_energy_j: float = 0.0

    def __post_init__(self):
        t = self.table
        tt = float(t.time[self.sequence, self.choice_seq].sum())
        ee = float(t.energy[self.sequence, self.choice_seq].sum())
        sw = int(np.sum(self.choice_seq[1:] != self.choice_seq[:-1]))
        self.n_switches = sw
        self.time_s = tt + sw * self.switch_latency_s
        self.energy_j = ee + sw * self.switch_energy_j
        self.base_time_s = float(t.time[self.sequence, t.auto_idx].sum())
        self.base_energy_j = float(t.energy[self.sequence, t.auto_idx].sum())

    @property
    def time_pct(self):
        return pct(self.time_s, self.base_time_s)

    @property
    def energy_pct(self):
        return pct(self.energy_j, self.base_energy_j)

    def summary(self) -> Dict:
        return {"plan": "coalesced-global",
                "switch_latency_s": self.switch_latency_s,
                "n_instances": len(self.sequence),
                "n_switches": self.n_switches,
                "time_pct": round(self.time_pct, 3),
                "energy_pct": round(self.energy_pct, 3),
                "time_s": self.time_s, "energy_j": self.energy_j,
                "base_time_s": self.base_time_s,
                "base_energy_j": self.base_energy_j}


def _dp_for_lambda(T: np.ndarray, E: np.ndarray, lam: float,
                   switch_t: float, switch_e: float) -> np.ndarray:
    """Per-instance DP for a single λ; returns choices (n,)."""
    return _dp_for_lambdas(T, E, np.asarray([lam]), switch_t, switch_e)[0]


def _dp_for_lambdas(T: np.ndarray, E: np.ndarray, lams: np.ndarray,
                    switch_t: float, switch_e: float) -> np.ndarray:
    """Batched-λ DP: solve the switch-cost Lagrangian for a whole *vector*
    of multipliers in one forward/backward sweep.

    The recurrence is inherently sequential in the instance axis, but every
    per-instance update is an (L, C) array op, so solving L multipliers
    costs one sweep instead of L — the λ bisection that used to run ~60
    sequential O(n) solves now runs 3–4 batched sweeps (`seconds →
    milliseconds for 10k-instance campaigns).

    Returns choices (L, n).
    """
    n, C = T.shape
    L = len(lams)
    lamc = np.asarray(lams, dtype=np.float64)[:, None]           # (L, 1)
    pen = switch_e + lamc * switch_t                             # (L, 1)
    lidx = np.arange(L)
    dp = E[0][None, :] + lamc * T[0][None, :]                    # (L, C)
    # backtrack state: whether state c stayed (vs switched from best_prev)
    stay = np.empty((n, L, C), dtype=bool)
    stay[0] = True
    best_prev = np.empty((n, L), dtype=np.int32)
    best_prev[0] = 0
    for i in range(1, n):
        bp = np.argmin(dp, axis=1)                               # (L,)
        move = dp[lidx, bp][:, None] + pen                       # (L, 1)
        # stay iff dp <= move, so the merged value is the elementwise min
        stay[i] = dp <= move
        best_prev[i] = bp
        np.minimum(dp, move, out=dp)
        dp += E[i][None, :] + lamc * T[i][None, :]
    choice = np.empty((L, n), dtype=np.int32)
    cur = np.argmin(dp, axis=1).astype(np.int32)                 # (L,)
    choice[:, -1] = cur
    for i in range(n - 1, 0, -1):
        cur = np.where(stay[i][lidx, cur], cur, best_prev[i])
        choice[:, i - 1] = cur
    return choice


def _dp_times(T: np.ndarray, E: np.ndarray, lams: np.ndarray,
              switch_t: float, switch_e: float):
    """Realized (time, energy) of the λ-optimal path, per λ.

    Forward-only twin of :func:`_dp_for_lambdas`: the realized time and
    energy of the best path ending in each state ride along the DP carry,
    so screening a whole λ grid for feasibility — and for the lowest
    feasible energy — needs no backtracking at all.  Returns a pair of
    (L,) arrays (seconds, joules), switch costs included.
    """
    n, C = T.shape
    L = len(lams)
    lamc = np.asarray(lams, dtype=np.float64)[:, None]
    pen = switch_e + lamc * switch_t
    lidx = np.arange(L)
    dp = E[0][None, :] + lamc * T[0][None, :]
    tdp = np.broadcast_to(T[0], (L, C)).copy()       # realized time per state
    edp = np.broadcast_to(E[0], (L, C)).copy()       # realized energy
    for i in range(1, n):
        bp = np.argmin(dp, axis=1)
        move = dp[lidx, bp][:, None] + pen
        use_stay = dp <= move
        tdp = np.where(use_stay, tdp,
                       (tdp[lidx, bp] + switch_t)[:, None]) + T[i][None, :]
        edp = np.where(use_stay, edp,
                       (edp[lidx, bp] + switch_e)[:, None]) + E[i][None, :]
        np.minimum(dp, move, out=dp)
        dp += E[i][None, :] + lamc * T[i][None, :]
    best = np.argmin(dp, axis=1)
    return tdp[lidx, best], edp[lidx, best]


def _splice_plans(T: np.ndarray, E: np.ndarray, chA: np.ndarray,
                  chB: np.ndarray, budget: float, switch_t: float,
                  switch_e: float):
    """Best prefix-A + suffix-B crossover under the time budget.

    The Lagrangian frontier is a step function with a duality gap: no
    single λ yields a plan *near* the budget when adjacent steps are far
    apart.  The classical repair is to splice the aggressive (infeasible)
    solution A with the conservative (feasible) B at one crossover point —
    all n candidate crossovers are evaluated with vectorized prefix/suffix
    sums, switch costs included.  Returns (choices, time, energy) of the
    best feasible splice (k = 0 degenerates to pure B, so a feasible B
    guarantees a result).
    """
    n = len(chA)
    iidx = np.arange(n)
    tA, eA = T[iidx, chA], E[iidx, chA]
    tB, eB = T[iidx, chB], E[iidx, chB]
    # prefix sums over A (instances < k) and suffix sums over B (>= k)
    preA_t = np.concatenate([[0.0], np.cumsum(tA)])
    preA_e = np.concatenate([[0.0], np.cumsum(eA)])
    sufB_t = np.concatenate([np.cumsum(tB[::-1])[::-1], [0.0]])
    sufB_e = np.concatenate([np.cumsum(eB[::-1])[::-1], [0.0]])
    swA = np.concatenate([[0, 0], np.cumsum(chA[1:] != chA[:-1])])[:n + 1]
    swB_rev = np.cumsum((chB[1:] != chB[:-1])[::-1])[::-1]
    swB = np.concatenate([swB_rev, [0, 0]])[:n + 1]
    cross = np.zeros(n + 1)
    cross[1:n] = chA[:n - 1] != chB[1:]
    sw = swA + swB + cross
    t = preA_t + sufB_t + sw * switch_t
    e = preA_e + sufB_e + sw * switch_e
    feas = t <= budget
    if not feas.any():
        return None
    e = np.where(feas, e, np.inf)
    k = int(np.argmin(e))
    return (np.concatenate([chA[:k], chB[k:]]).astype(np.int32),
            float(t[k]), float(e[k]))


def coalesced_global_plan(table: MeasurementTable,
                          policy: Optional[WastePolicy] = None,
                          switch_latency_s: Optional[float] = None,
                          switch_power_w: float = SWITCH_POWER_W,
                          sequence: Optional[np.ndarray] = None
                          ) -> CoalescedPlan:
    """Energy-min plan under the time budget *including* switch costs."""
    policy = policy if policy is not None else WastePolicy()
    seq = expand_sequence(table) if sequence is None else sequence
    T = table.time[seq]
    E = table.energy[seq]
    sl = switch_latency_s if switch_latency_s is not None else 1e-6
    se = switch_power_w * sl
    t_base = float(table.time[seq, table.auto_idx].sum())
    budget = policy.budget(t_base)

    def solve_one(lam: float):
        ch = _dp_for_lambdas(T, E, np.asarray([lam]), sl, se)[0]
        sw = int(np.sum(ch[1:] != ch[:-1]))
        return ch, float(T[np.arange(len(seq)), ch].sum()) + sw * sl

    # feasibility screen: the λ=0 point and a geometric bracket grid in one
    # forward-only batched sweep (replaces the sequential ×8 bracket + the
    # 60-step bisection, each a full O(n) DP, of the scalar solver)
    grid = np.concatenate([[0.0], 8.0 ** np.arange(0, 23)])      # 0, 1…6e20
    ts, es = _dp_times(T, E, grid, sl, se)
    feas = ts <= budget
    bracket = None
    if feas[0]:
        lam = 0.0
    elif feas.any():
        # best feasible candidate seen so far (λ-time curve is a step
        # function; the lowest-energy feasible *evaluated* point wins)
        cand = np.where(feas, es, np.inf)
        lam = float(grid[int(np.argmin(cand))])
        best_e = float(cand.min())
        j = int(np.argmax(feas[1:])) + 1          # smallest feasible λ
        lo, hi = float(grid[j - 1]), float(grid[j])
        # refine: batched 16-point sweeps shrink the bracket 15x per
        # sweep (2 sweeps: ×8 -> ~1% relative).  That is enough to
        # isolate the two frontier *steps* straddling the budget; the
        # splice repair below fills the duality gap between them, so the
        # λ boundary itself never needs float-precision convergence.
        # 3 sweeps total replace ~64 sequential DP solves.
        for _ in range(2):
            if hi <= lo * (1.0 + 1e-9):
                break
            inner = np.geomspace(max(lo, hi / 512.0), hi, 16)
            its, ies = _dp_times(T, E, inner, sl, se)
            ifeas = its <= budget
            icand = np.where(ifeas, ies, np.inf)
            if icand.min() < best_e:
                best_e = float(icand.min())
                lam = float(inner[int(np.argmin(icand))])
            j = int(np.argmax(ifeas))             # inner[-1] == hi feasible
            lo = float(inner[j - 1]) if j > 0 else lo
            hi = float(inner[j])
        bracket = (lo, hi)
    else:
        lam = float(grid[-1])
    if bracket is None:
        ch, t = solve_one(lam)
    else:
        # one batched backtrack recovers the best-λ candidate plus the
        # aggressive/conservative step solutions straddling the budget
        lo, hi = bracket
        chs = _dp_for_lambdas(T, E, np.asarray([lam, lo, hi]), sl, se)
        iidx = np.arange(len(seq))

        def realize(c):
            sw = int(np.sum(c[1:] != c[:-1]))
            return (float(T[iidx, c].sum()) + sw * sl,
                    float(E[iidx, c].sum()) + sw * se)

        ch = chs[0]
        t, e_cur = realize(ch)
        # primal repair across the duality gap: the λ frontier steps over
        # the budget, so splice the aggressive path (just below λ*) with
        # the conservative one at the best single crossover
        for a, b in ((chs[1], chs[2]), (chs[2], chs[1])):
            spl = _splice_plans(T, E, a, b, budget, sl, se)
            if spl is not None and spl[2] < e_cur:
                ch, t, e_cur = spl[0], spl[1], spl[2]
    if t > budget:  # infeasible even at huge λ -> stay on auto
        ch = np.full(len(seq), table.auto_idx, dtype=np.int32)
    return CoalescedPlan(choice_seq=ch, sequence=seq, table=table,
                         switch_latency_s=sl, switch_energy_j=se)
