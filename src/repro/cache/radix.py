"""Radix prefix index over the paged-KV pool.

Production traffic shares prefixes — system prompts, few-shot templates,
per-tenant preambles — so the K/V a prefill writes for one request is
byte-reusable by the next request carrying the same leading tokens:
under causal masking (with ``prompt_lens`` masking the right-padding)
position ``i``'s K/V depends only on tokens ``0..i``, so identical
prefixes produce identical pages whatever follows them.  The
:class:`RadixCache` indexes finished prefills by their token ids at
**page granularity**: a trie whose edges are ``page_size``-token chunks
and whose nodes each own exactly one resident page of the
:class:`~repro.serve.kv_pages.PagePool`.

* **Adoption** (:meth:`insert`) — after a prefill completes, every fully
  valid page of the prompt is offered to the tree.  New paths retain the
  page (``pool.retain_page``: refcount + 1, no block-table change, so
  the device-mirror dirty flag stays clean); already-known chunks keep
  their existing page and the caller's duplicate stays slot-private.
* **Lookup** (:meth:`match`) — the longest chunk-aligned walk from the
  root returns the shared pages a new request can splice into its block
  table instead of re-prefilling; an optional *tail* probe additionally
  finds a child sharing a partial chunk (≥ 1 leading token) — the
  copy-on-write case, since the requester will write the divergent rest
  of that page.
* **Eviction** (:meth:`evict`) — under pool pressure the evictor
  reclaims **only pages the tree alone still references** (pool
  refcount 1; a page any slot is reading is never yanked), cascading
  leaf-upward in seeded-LRU order: coldest leaves go first, interior
  nodes become reclaimable once their (necessarily colder-or-equal)
  subtrees are gone.  Ties on the access clock break by a per-node salt
  drawn from the cache's seeded RNG, keeping multi-replica simulations
  reproducible.

Namespaces isolate requests whose K/V depends on more than the token
ids: encoder-decoder requests (cross-attention and self-K/V depend on
the encoder frames) and vision requests (patch rows occupy cache
positions and shift everything behind them) key their sub-trie by a
fingerprint of the extra conditioning (:func:`extras_namespace`), so
only requests with bit-identical extras can share pages.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["RadixCache", "extras_namespace"]


def extras_namespace(extras: Optional[Dict]) -> int:
    """Deterministic fingerprint of a request's non-token conditioning.

    Hashes every extra leaf's name, shape, dtype and raw bytes; requests
    with no extras share namespace 0.  Two requests land in the same
    namespace (and may share prefix pages) only when their conditioning
    is bit-identical — the conservative rule that keeps encoder-decoder
    and vision-prefixed caches sound.
    """
    if not extras:
        return 0
    h = hashlib.blake2b(digest_size=8)
    for k in sorted(extras):
        v = np.asarray(extras[k])
        h.update(k.encode())
        h.update(repr((v.shape, str(v.dtype))).encode())
        h.update(np.ascontiguousarray(v).tobytes())
    return 1 + int.from_bytes(h.digest(), "big")


class _Node:
    """One resident page: the chunk of token ids that fills it, the page
    id backing it, and LRU bookkeeping."""

    __slots__ = ("chunk", "page", "parent", "children", "last_used",
                 "salt")

    def __init__(self, chunk, page: int, parent: Optional["_Node"],
                 salt: float = 0.0):
        self.chunk = chunk
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0
        self.salt = salt


class RadixCache:
    """Page-granular radix index with refcount-guarded seeded-LRU
    eviction (see module docstring)."""

    def __init__(self, page_size: int, seed: int = 0):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = int(page_size)
        self._roots: Dict[int, _Node] = {}
        self._rng = np.random.default_rng(seed)
        self._clock = 0
        self.n_nodes = 0
        self.hits = 0           # lookups that matched >= 1 token
        self.misses = 0
        self.hit_tokens = 0     # tokens served from the tree
        self.lookup_tokens = 0  # tokens asked of the tree

    # -- helpers ---------------------------------------------------------
    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_used = self._clock

    def _chunk(self, tokens: Sequence[int], i: int) -> Tuple[int, ...]:
        return tuple(int(t) for t in
                     tokens[i * self.page_size:(i + 1) * self.page_size])

    # -- lookup ----------------------------------------------------------
    def match(self, tokens: Sequence[int], ns: int = 0,
              tail: bool = False, touch: bool = True
              ) -> Tuple[List[int], int, Optional[Tuple[int, int]]]:
        """Longest cached prefix of ``tokens``.

        Returns ``(pages, matched_tokens, tail_hit)``: the chunk-aligned
        shared pages, the token count they cover, and — with ``tail`` —
        an optional ``(page, k)`` for a child whose chunk shares ``k``
        leading tokens with the unmatched remainder (the copy-on-write
        splice).  ``touch=False`` makes the lookup a pure probe (router
        scoring): no LRU motion, no hit/miss accounting.
        """
        root = self._roots.get(int(ns))
        toks = [int(t) for t in tokens]
        pages: List[int] = []
        matched = 0
        tail_hit: Optional[Tuple[int, int]] = None
        node = root
        if node is not None:
            while matched + self.page_size <= len(toks):
                child = node.children.get(
                    self._chunk(toks, matched // self.page_size))
                if child is None:
                    break
                pages.append(child.page)
                matched += self.page_size
                node = child
                if touch:
                    self._touch(child)
            if tail and matched < len(toks):
                rem = toks[matched:]
                best_k, best = 0, None
                for chunk, child in sorted(node.children.items()):
                    k = 0
                    for a, b in zip(rem, chunk):
                        if a != b:
                            break
                        k += 1
                    if k > best_k:
                        best_k, best = k, child
                if best is not None:
                    tail_hit = (best.page, best_k)
                    if touch:
                        self._touch(best)
        if touch:
            got = matched + (tail_hit[1] if tail_hit else 0)
            self.hits += 1 if got else 0
            self.misses += 0 if got else 1
            self.hit_tokens += got
            self.lookup_tokens += len(toks)
        return pages, matched, tail_hit

    # -- adoption --------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int], pool,
               ns: int = 0) -> int:
        """Adopt a finished prefill's fully-valid pages.

        ``pages[i]`` must back ``tokens[i*page : (i+1)*page]`` — callers
        pass only pages every position of which holds valid prompt K/V.
        New chunks are retained in the pool; chunks already in the tree
        keep their incumbent page (the caller's copy stays slot-private
        and dies with the slot).  Returns the number of pages adopted.
        """
        node = self._roots.setdefault(
            int(ns), _Node(None, -1, None))
        toks = [int(t) for t in tokens]
        adopted = 0
        for i, page in enumerate(pages):
            chunk = self._chunk(toks, i)
            if len(chunk) < self.page_size:
                break
            child = node.children.get(chunk)
            if child is None:
                pool.retain_page(int(page))
                child = _Node(chunk, int(page), node,
                              salt=float(self._rng.random()))
                node.children[chunk] = child
                self.n_nodes += 1
                adopted += 1
            self._touch(child)
            node = child
        return adopted

    # -- eviction --------------------------------------------------------
    def _evictable(self, pool) -> Optional[_Node]:
        """Coldest leaf whose page only the tree holds (refcount 1)."""
        best, best_key = None, None
        for root in self._roots.values():
            stack = [root]
            while stack:
                nd = stack.pop()
                for c in nd.children.values():
                    if c.children:
                        stack.append(c)
                    elif pool.refcounts[c.page] == 1:
                        key = (c.last_used, c.salt)
                        if best_key is None or key < best_key:
                            best, best_key = c, key
        return best

    def evict(self, pool, n_pages: int = 1) -> int:
        """Reclaim up to ``n_pages`` tree-only pages in LRU order,
        cascading leaf-upward (a parent becomes a candidate leaf once
        its subtree is gone).  Returns the number actually freed —
        pinned pages (any slot still mapping them) are never touched, so
        the count may fall short under heavy sharing.
        """
        freed = 0
        while freed < n_pages:
            node = self._evictable(pool)
            if node is None:
                break
            pool.evict_page(node.page)
            del node.parent.children[node.chunk]
            self.n_nodes -= 1
            freed += 1
        return freed

    def flush(self, pool) -> int:
        """Drop every tree reference (pool pages a slot still maps stay
        alive through the slot's own refcount).  Returns nodes released."""
        released = 0
        for root in self._roots.values():
            stack = list(root.children.values())
            root.children.clear()
            while stack:
                nd = stack.pop()
                stack.extend(nd.children.values())
                pool.release_page(nd.page)
                released += 1
        self._roots.clear()
        self.n_nodes = 0
        return released

    # -- accounting ------------------------------------------------------
    def stats(self) -> Dict:
        looks = self.hits + self.misses
        return {"nodes": self.n_nodes,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / looks if looks else 0.0,
                "hit_tokens": self.hit_tokens,
                "lookup_tokens": self.lookup_tokens,
                "token_hit_rate": (self.hit_tokens / self.lookup_tokens
                                   if self.lookup_tokens else 0.0)}
