"""repro.cache — radix prefix cache over the paged-KV pool.

Shares finished prefills across requests: a trie keyed on
page-granularity token chunks maps known prefixes to resident
:class:`~repro.serve.kv_pages.PagePool` pages, which admission splices
into new slots' block tables read-only (copy-on-write on divergence).
See :mod:`repro.cache.radix` for the data structure and the sharing /
eviction rules.
"""
from .radix import RadixCache, extras_namespace

__all__ = ["RadixCache", "extras_namespace"]
