"""Quickstart: the whole pipeline in one page, via the repro.dvfs facade.

1. Decompose a GPT-3-xl training iteration into kernels (paper Table 1).
2. Run the simulated DVFS measurement campaign (paper §4).
3. Plan with three governors from the registry: strict-waste kernel-level
   global optimum vs pass-level vs EDP.
4. Compile the winning plan into the unified, versioned DvfsPlan IR and
   save it (the artifact a DvfsSession executor replays).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config, get_shape
from repro.core import Campaign, build_workload, get_chip
from repro.dvfs import DvfsPlan, governor


def main():
    cfg = get_config("gpt3-xl")
    shape = get_shape("paper_gpt3xl")
    kernels = build_workload(cfg, shape)
    print(f"workload: {len(kernels)} kernels / iteration "
          f"({sum(k.invocations for k in kernels)} launches)")

    chip = get_chip("rtx3080ti")
    camp = Campaign(chip, seed=0, n_reps=5)
    table = camp.run(kernels)
    tb, eb = table.baseline_totals()
    print(f"auto baseline: {tb*1e3:.0f} ms/iter, {eb:.0f} J/iter")

    for name, kw in (("pass-level", {}), ("kernel-static", {}),
                     ("edp", {"level": "global"})):
        s = governor(name, **kw).solve(table).summary()
        print(f"  {s['plan']:14s} time {s['time_pct']:+7.2f}%  "
              f"energy {s['energy_pct']:+7.2f}%")

    gov = governor("kernel-static")
    plan = gov.plan_table(table, meta={"model": cfg.name,
                                       "shape": shape.name})
    seg = plan.segment("iteration")
    print(f"plan IR: schema v{plan.schema_version}, "
          f"{len(plan.segments)} segment(s), "
          f"{len(seg.schedule.entries)} coalesced entries, "
          f"{seg.schedule.n_switches} clock switches per iteration")
    plan.save("artifacts/quickstart_plan.json")
    print("saved artifacts/quickstart_plan.json "
          f"(round-trips: {DvfsPlan.load('artifacts/quickstart_plan.json').summary() == plan.summary()})")


if __name__ == "__main__":
    main()
