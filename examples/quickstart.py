"""Quickstart: the whole pipeline in one page.

1. Decompose a GPT-3-xl training iteration into kernels (paper Table 1).
2. Run the simulated DVFS measurement campaign (paper §4).
3. Plan: strict-waste kernel-level global optimum vs pass-level vs EDP.
4. Compile the plan into a deployable DVFS schedule.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config, get_shape
from repro.core import (Campaign, WastePolicy, build_workload,
                        edp_global_plan, get_chip, global_plan,
                        pass_level_plan, schedule_from_plan)


def main():
    cfg = get_config("gpt3-xl")
    shape = get_shape("paper_gpt3xl")
    kernels = build_workload(cfg, shape)
    print(f"workload: {len(kernels)} kernels / iteration "
          f"({sum(k.invocations for k in kernels)} launches)")

    chip = get_chip("rtx3080ti")
    camp = Campaign(chip, seed=0, n_reps=5)
    table = camp.run(kernels)
    tb, eb = table.baseline_totals()
    print(f"auto baseline: {tb*1e3:.0f} ms/iter, {eb:.0f} J/iter")

    for plan in (pass_level_plan(table, WastePolicy(0.0)),
                 global_plan(table, WastePolicy(0.0)),
                 edp_global_plan(table)):
        s = plan.summary()
        print(f"  {s['plan']:14s} time {s['time_pct']:+7.2f}%  "
              f"energy {s['energy_pct']:+7.2f}%")

    plan = global_plan(table, WastePolicy(0.0))
    sched = schedule_from_plan(plan)
    print(f"schedule: {len(sched.entries)} coalesced entries, "
          f"{sched.n_switches} clock switches per iteration")
    sched.save("artifacts/quickstart_schedule.json")
    print("saved artifacts/quickstart_schedule.json")


if __name__ == "__main__":
    main()
