"""Serving example: continuous batching + executed phase-aware DVFS +
online re-planning, all through the repro.dvfs facade.

Decode workloads are HBM-bound (weight + KV-cache streaming), so the
waste planner finds much deeper core-clock reductions than in training —
the paper's §11 inference outlook, made concrete.  One
:class:`~repro.dvfs.DvfsSession` plans every serving phase (prefill plan
+ decode plans keyed by active-slot bucket) and the engine *executes*
the plan through the session's governor executor at every phase
transition.

The second half shows the :class:`~repro.dvfs.OnlineGovernor`: the same
plan under a drifted traffic mix strands time budget; the governor
detects the bucket-mix drift from runtime feedback, re-plans the decode
segments jointly over the observed mix (off the hot path), and recovers
the stranded energy.

Run:  PYTHONPATH=src python examples/serve_dvfs.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import REGISTRY, smoke_config
from repro.configs.base import ShapeConfig
from repro.core import (Campaign, WastePolicy, WorkloadBuilder,
                        decode_slot_buckets)
from repro.dvfs import (DvfsSession, OnlineGovernor, ServeGovernorExecutor,
                        StaticPlanGovernor, plan_decode_joint)
from repro.models import build_model
from repro.serve import Request, ServeEngine

SLOTS = 4
TAU = 0.005


def main():
    # --- offline: one session plans every serving phase -----------------
    full = REGISTRY["llama3.2-1b"]
    prefill = ShapeConfig(name="serve_prefill", seq_len=512,
                          global_batch=1, kind="prefill")
    decode = ShapeConfig(name="serve_decode", seq_len=512,
                         global_batch=SLOTS, kind="decode")
    sess = DvfsSession(chip="tpu-v5e", tau=TAU, n_reps=10)
    plan = sess.plan_serve(full, n_slots=SLOTS, prefill_shape=prefill,
                           decode_shape=decode)
    plan.save("artifacts/serve_phase_bundle.json")
    print("planned phases (governor=kernel-static):")
    for name, row in plan.summary()["phases"].items():
        print(f"  {name:10s} time {row['time_pct']:+7.3f}%  "
              f"energy {row['energy_pct']:+8.3f}%  "
              f"switches/step {row['n_switches']}")

    # --- online: continuous-batching engine executes the plan -----------
    cfg = dataclasses.replace(smoke_config(full), compute_dtype="float32")
    model = build_model(cfg, block_k=16)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=SLOTS, max_seq=96,
                         executor=sess.serve_executor())

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               rng.integers(4, 12)),
                    max_new_tokens=int(rng.integers(4, 24)))
            for i in range(10)]
    engine.generate(reqs)
    for r in reqs[:3]:
        print(f"req {r.uid}: prompt[{len(r.prompt)}] -> "
              f"{len(r.generated)} tokens, done at step {r.finished_step}")

    tot = engine.energy_summary()["totals"]
    print(f"executed: {tot['steps']} phase steps, "
          f"{tot['n_switches']} clock switches, "
          f"time {tot['time_pct']:+.4f}% vs auto, "
          f"energy {tot['energy_pct']:+.3f}% vs auto")
    sess.close()

    # --- the online governor on a drifted traffic mix -------------------
    chip = sess.chip
    policy = WastePolicy(0.01)
    camp = Campaign(chip, seed=0, n_reps=5)
    tables = {b: camp.run(WorkloadBuilder(full, decode,
                                          batch_override=b).build())
              for b in decode_slot_buckets(SLOTS)}
    planned_mix = {1: 0.30, 2: 0.30, 4: 0.40}
    drift = [1] * 2 + [2] * 13 + [4]       # observed mix ~ {.12,.81,.06}

    def serve_plan(mix):
        from repro.dvfs import DvfsPlan, PlanSegment
        from repro.core import compile_phase
        segs = plan_decode_joint(tables, mix, chip, policy)
        pre = PlanSegment.from_phase_plan(
            compile_phase(tables[1], "prefill", chip, policy),
            scope="serve-prefill")
        return DvfsPlan(chip_name=chip.name, kind="serve",
                        segments=[pre] + segs,
                        meta={"decode_mix": dict(mix)})

    gov = OnlineGovernor(serve_plan(planned_mix), policy=policy,
                         chip=chip, tables=tables, window=32)
    online = ServeGovernorExecutor(gov, chip)
    stale = ServeGovernorExecutor(
        StaticPlanGovernor(serve_plan(planned_mix)), chip)
    for i in range(320):
        online.on_decode(drift[i % len(drift)])
        stale.on_decode(drift[i % len(drift)])
    online.finish(), stale.finish()
    ev = gov.events[-1]
    print(f"\nonline governor: re-planned at revision {gov.revision} "
          f"({ev['reason']})")
    on, st = online.summary()["totals"], stale.summary()["totals"]
    print(f"  stale plan : time {st['time_pct']:+.4f}%  "
          f"energy {st['energy_pct']:+.4f}%")
    print(f"  online     : time {on['time_pct']:+.4f}%  "
          f"energy {on['energy_pct']:+.4f}%  "
          f"(recovered {st['energy_j'] - on['energy_j']:.3f} J of "
          f"stranded budget on the drifted mix)")


if __name__ == "__main__":
    main()
