"""Serving example: batched generation + decode-phase DVFS planning.

Decode workloads are HBM-bound (weight + KV-cache streaming), so the
strict-waste planner finds much deeper core-clock reductions than in
training — the paper's §11 inference outlook, made concrete.

Run:  PYTHONPATH=src python examples/serve_dvfs.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import REGISTRY, get_shape, smoke_config
from repro.core import (Campaign, WastePolicy, build_workload, get_chip,
                        global_plan)
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main():
    cfg = smoke_config(REGISTRY["llama3.2-1b"])
    model = build_model(cfg, block_k=16)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=4, max_seq=96)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               rng.integers(4, 12)),
                    max_new_tokens=8) for i in range(6)]
    out = engine.generate(reqs)
    for r in out[:3]:
        print(f"req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")

    # --- DVFS plans per serving phase (full-size arch) ---
    full = REGISTRY["llama3.2-1b"]
    chip = get_chip("tpu-v5e")
    for sname in ("prefill_32k", "decode_32k"):
        kernels = build_workload(full, get_shape(sname), tp=16, dp=16)
        table = Campaign(chip, seed=1, n_reps=5).run(kernels)
        plan = global_plan(table, WastePolicy(0.0))
        print(f"{sname:12s}: {plan.energy_pct:+7.2f}% energy at "
              f"{plan.time_pct:+.2f}% time (strict waste, "
              f"{len(kernels)} kernels)")


if __name__ == "__main__":
    main()
