"""Serving example: continuous batching + executed phase-aware DVFS.

Decode workloads are HBM-bound (weight + KV-cache streaming), so the
waste planner finds much deeper core-clock reductions than in training —
the paper's §11 inference outlook, made concrete.  Unlike the offline
planning demos, the plan here is *executed*: the engine replays a
``PhasePlanBundle`` (prefill plan + decode plans keyed by active-slot
bucket) through ``FrequencyController``/``EnergyMeter`` hooks at every
phase transition, and reports the realized energy account.

Run:  PYTHONPATH=src python examples/serve_dvfs.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import REGISTRY, smoke_config
from repro.configs.base import ShapeConfig
from repro.core import WastePolicy, get_chip, plan_phase_bundle
from repro.models import build_model
from repro.runtime import PhaseExecutor
from repro.serve import Request, ServeEngine

SLOTS = 4


def main():
    # --- offline: plan every serving phase of the full-size arch --------
    full = REGISTRY["llama3.2-1b"]
    chip = get_chip("tpu-v5e")
    prefill = ShapeConfig(name="serve_prefill", seq_len=512,
                          global_batch=1, kind="prefill")
    decode = ShapeConfig(name="serve_decode", seq_len=512,
                         global_batch=SLOTS, kind="decode")
    bundle = plan_phase_bundle(full, chip, n_slots=SLOTS,
                               prefill_shape=prefill, decode_shape=decode,
                               policy=WastePolicy(0.005), n_reps=10)
    bundle.save("artifacts/serve_phase_bundle.json")
    print("planned phases:")
    for name, row in bundle.summary()["phases"].items():
        print(f"  {name:10s} time {row['time_pct']:+7.3f}%  "
              f"energy {row['energy_pct']:+8.3f}%  "
              f"switches/step {row['n_switches']}")

    # --- online: continuous-batching engine executes the bundle ---------
    cfg = dataclasses.replace(smoke_config(full), compute_dtype="float32")
    model = build_model(cfg, block_k=16)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=SLOTS, max_seq=96,
                         executor=PhaseExecutor(bundle, chip))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               rng.integers(4, 12)),
                    max_new_tokens=int(rng.integers(4, 24)))
            for i in range(10)]
    engine.generate(reqs)
    for r in reqs[:3]:
        print(f"req {r.uid}: prompt[{len(r.prompt)}] -> "
              f"{len(r.generated)} tokens, done at step {r.finished_step}")

    tot = engine.energy_summary()["totals"]
    print(f"executed: {tot['steps']} phase steps, "
          f"{tot['n_switches']} clock switches, "
          f"time {tot['time_pct']:+.4f}% vs auto, "
          f"energy {tot['energy_pct']:+.3f}% vs auto")


if __name__ == "__main__":
    main()
