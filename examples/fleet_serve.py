"""Fleet example: route one trace three ways, then cap the cluster.

The fleet tier composes everything below it: each replica runs its own
kernel-level DVFS plan (PR 1-4), the router reads those plans to predict
marginal energy, and the :class:`~repro.fleet.FleetGovernor` solves one
shared Lagrangian budget across replicas to hold a cluster power cap —
pushing revised plans through each replica's online re-plan path.

Three stages:

1. Generate a seeded peak-load trace (Poisson arrivals, heavy-tailed
   generation lengths) and replay it through round-robin, least-queue,
   and the energy/SLO-aware router: same requests, three energy/tail
   outcomes.
2. Re-serve under a cluster power cap 5% below the fleet's natural
   draw and watch the governor's control ticks track it.
3. Drain and park a replica mid-trace: autoscale-down as one more DVFS
   decision (the parked state is the chip's deepest frequency pair).

Run:  PYTHONPATH=src python examples/fleet_serve.py
"""
from repro.configs import REGISTRY
from repro.fleet import (FleetGovernor, ReplicaSpec, build_fleet,
                         generate_trace, router)

CFG = REGISTRY["llama3.2-1b"]
SPECS = [ReplicaSpec(chip="tpu-v5e", n_slots=4, tau=0.005)] * 3
RKW = dict(slo_ttft_s=0.08, slo_weight=60.0, slack=0.3)


def serve(router_obj, trace, governor=None, autopark=None):
    fleet = build_fleet(SPECS, CFG, router=router_obj, n_reps=3,
                        fleet_governor=governor,
                        autopark_idle_s=autopark)
    return fleet.serve(trace), fleet


def main():
    trace = generate_trace("poisson", n_requests=200, rate_rps=80.0,
                           seed=0, straggler_tokens=64, straggler_every=3)
    print(f"trace: {len(trace)} requests over {trace.duration_s:.1f}s, "
          f"{trace.total_new_tokens} tokens to generate")

    # --- 1. one trace, three routers --------------------------------
    for name in ("round-robin", "least-queue", "energy-slo"):
        rt = router(name, **RKW) if name == "energy-slo" else name
        rep, _ = serve(rt, trace)
        print(f"  {name:12s}: {rep['joules_per_token']:.4f} J/tok, "
              f"TTFT p99 {rep['ttft_p99_s']*1e3:5.0f} ms, "
              f"idle {rep['idle_energy_j']:5.0f} J")

    # --- 2. cluster power cap ---------------------------------------
    rep, _ = serve(router("energy-slo", **RKW), trace)
    cap = 0.95 * rep["power"]["mean_loaded_w"]
    capped, fleet = serve(router("energy-slo", **RKW), trace,
                          governor=FleetGovernor(cap, interval_s=0.25))
    p = capped["power"]
    print(f"cap {cap:.0f} W: mean loaded {p['mean_loaded_w']:.1f} W "
          f"(tracking err {p['loaded_tracking_err_frac']*100:.2f}%), "
          f"makespan {capped['makespan_s']:.2f}s vs "
          f"{rep['makespan_s']:.2f}s uncapped, "
          f"{capped['fleet_governor']['n_replans']} pushed re-plans")
    ticks = [e for e in fleet.governor.events if not e.get("hold")][:3]
    for e in ticks:
        print(f"   t={e['t']:.2f}s predicted {e['predicted_w']:.0f} W, "
              f"lambda={e['lambda']:.2e}, pushed "
              f"{[pp['replica'] for pp in e['pushed']]}")

    # --- 3. drain + park = autoscale-down ---------------------------
    rep, fleet = serve(router("energy-slo", **RKW),
                       generate_trace("diurnal", n_requests=120,
                                      rate_rps=25.0, seed=0),
                       autopark=0.25)
    for b in rep["replicas"]:
        print(f"  {b['name']:12s}: busy {b['busy_s']:.2f}s idle "
              f"{b['idle_s']:.2f}s parked {b['parked_s']:.2f}s "
              f"({b['parked_energy_j']:.0f} J at "
              f"{fleet.replicas[0].parked_power_w:.0f} W deepest-state)"
              f" -> {b['state']}")


if __name__ == "__main__":
    main()
