"""End-to-end training driver: GPT-3-xl-family model + executed
kernel-level DVFS.

Trains a reduced GPT-3 on the synthetic corpus with the fault-tolerant
Trainer (checkpoint/restart, straggler watchdog) while a
:class:`~repro.dvfs.DvfsSession` executor *executes* the planned
fwd/bwd/opt clock schedules around every step — per-phase frequency
actuation plus exact per-phase energy accounting vs the auto governor.
An injected failure exercises the restart path, including mid-plan
resume of the executor's books; the unified ``DvfsPlan`` IR is saved to
artifacts/train_plan_bundle.json.

Run:  PYTHONPATH=src python examples/train_gpt3xl_dvfs.py \\
          [--steps 60] [--d-model 256] [--layers 4] [--full]
(--full uses the true 1.3B config — sized for a real cluster, not this CPU)
"""
import argparse
import dataclasses

from repro.configs import get_config, get_shape
from repro.ckpt import CheckpointManager
from repro.data import DataPipeline
from repro.dvfs import DvfsSession
from repro.models import build_model
from repro.runtime import FailureInjector
from repro.train import OptimizerConfig, make_train_step
from repro.train.loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/train_gpt3xl")
    ap.add_argument("--fail-at", type=int, default=25,
                    help="inject a failure at this step (FT drill)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from existing checkpoints (default: fresh)")
    args = ap.parse_args()
    if not args.resume:
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = get_config("gpt3-xl")
    if not args.full:
        cfg = dataclasses.replace(
            cfg, n_layers=args.layers, d_model=args.d_model,
            d_ff=4 * args.d_model, n_heads=8, n_kv_heads=8, head_dim=0,
            vocab_size=args.vocab, max_train_seq=args.seq)
    total, _ = cfg.param_count()
    print(f"model: {total/1e6:.1f}M params")

    # --- DVFS plan for this training iteration (repro.dvfs facade) ---
    shape = dataclasses.replace(get_shape("paper_gpt3xl"),
                                seq_len=args.seq,
                                global_batch=args.batch)
    # tpu-v5e: IVR-class switch latency makes per-kernel DVFS realizable
    session = DvfsSession(chip="tpu-v5e", tau=0.006, n_reps=5)
    plan = session.plan_train(cfg, shape=shape)
    plan.save("artifacts/train_plan_bundle.json")
    for ph, row in plan.summary()["phases"].items():
        print(f"  {ph:4s} plan: {row['energy_pct']:+7.2f}% energy at "
              f"{row['time_pct']:+6.2f}% time "
              f"({row['n_switches']} switches)")

    # --- fault-tolerant training with executed DVFS ---
    model = build_model(cfg, block_k=64)
    step = make_train_step(model, OptimizerConfig(lr=3e-3, warmup_steps=10,
                                                  decay_steps=args.steps),
                           accum_steps=2, remat=False)
    pipeline = DataPipeline(vocab_size=cfg.vocab_size,
                            batch_per_host=args.batch, seq_len=args.seq)
    trainer = Trainer(
        model, step, pipeline,
        CheckpointManager(args.ckpt_dir, keep=2),
        TrainerConfig(total_steps=args.steps, ckpt_every=10, log_every=10),
        executor=session.train_executor(),
        failure_injector=FailureInjector(
            [args.fail_at] if args.fail_at >= 0 else []))
    out = trainer.run()
    session.close()

    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {out['final_step']} steps "
          f"({out['restarts']} restart(s) from injected failures)")
    tot = out["dvfs"]["totals"]
    print(f"executed DVFS: {tot['energy_pct']:+.2f}% energy at "
          f"{tot['time_pct']:+.2f}% time vs auto "
          f"({tot['n_switches']} clock switches over "
          f"{tot['steps']} phase executions)")


if __name__ == "__main__":
    main()
