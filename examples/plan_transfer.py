"""Plan transfer across parallelism (paper §7/§8 as a workflow).

Discover the strict-waste plan once (batch 40, TP=1), then apply it to
data-parallel (smaller per-chip batch) and tensor-parallel (sharded
kernels) variants — the deployment pattern for a 1000-node fleet: one
3-GPU-day campaign, one plan, every worker.

Run:  PYTHONPATH=src python examples/plan_transfer.py
"""
from repro.configs import get_config, get_shape
from repro.core import Campaign, build_workload, get_chip
from repro.dvfs import governor


def main():
    cfg = get_config("gpt3-xl")
    shape = get_shape("paper_gpt3xl")
    chip = get_chip("rtx3080ti")

    kernels = build_workload(cfg, shape)
    table = Campaign(chip, seed=0, n_reps=5).run(kernels)
    plan = governor("kernel-static").solve(table)
    print(f"discovered (batch 40, TP=1): {plan.energy_pct:+.2f}% energy, "
          f"{plan.time_pct:+.2f}% time")

    print("\n-- data parallelism (per-chip batch) --")
    for b in (20, 8, 2, 1):
        t2 = Campaign(chip, seed=50 + b, n_reps=5).run(
            build_workload(cfg, shape, batch_override=b))
        t, e = t2.totals(plan.choice)
        tb, eb = t2.baseline_totals()
        print(f"  batch {b:3d}: {100*(e/eb-1):+7.2f}% energy, "
              f"{100*(t/tb-1):+6.2f}% time")

    print("\n-- tensor parallelism (+ sequence parallel) --")
    for d in (2, 4, 8, 16):
        t2 = Campaign(chip, seed=80 + d, n_reps=5).run(
            build_workload(cfg, shape, tp=d, sp=True))
        t, e = t2.totals(plan.choice)
        tb, eb = t2.baseline_totals()
        print(f"  tp {d:2d}: {100*(e/eb-1):+7.2f}% energy, "
              f"{100*(t/tb-1):+6.2f}% time")
    print("\nsavings transfer within a few pp — one campaign serves the "
          "whole fleet (paper §7-8).")


if __name__ == "__main__":
    main()
