"""Beyond-paper: kernel-level strict-waste DVFS across every assigned
architecture x shape, on the TPU-v5e-like chip.

This is the paper's technique deployed as a framework feature: per-cell
kernel decomposition -> simulated campaign -> global strict-waste plan.
Decode workloads (HBM-bound cache reads) show the largest headroom; MoE
adds ICI-bound dispatch kernels; SSM narrows the spread.
"""
from __future__ import annotations

import numpy as np

from repro.configs import all_cells, get_config, get_shape
from repro.core import Campaign, build_workload, get_chip
from .common import save_artifact, solve


def main(verbose: bool = True, chip_name: str = "tpu-v5e"):
    chip = get_chip(chip_name)
    rows = []
    for arch, sname, ok, why in all_cells(include_skipped=False):
        cfg = get_config(arch)
        shape = get_shape(sname)
        kernels = build_workload(cfg, shape, tp=16, dp=16, sp=True,
                                 include_comm=True)
        camp = Campaign(chip, seed=hash((arch, sname)) % 2**31, n_reps=5)
        table = camp.run(kernels)
        plan = solve(table, "kernel-static")
        rows.append({"arch": arch, "shape": sname,
                     "n_kernels": len(kernels),
                     "time_pct": plan.time_pct,
                     "energy_pct": plan.energy_pct})
        if verbose:
            r = rows[-1]
            print(f"[dvfs_by_arch] {arch:24s} {sname:12s} "
                  f"e={r['energy_pct']:+7.2f}% (t={r['time_pct']:+5.2f}%, "
                  f"{r['n_kernels']} kernels)")
    by_kind = {}
    for r in rows:
        by_kind.setdefault(r["shape"], []).append(r["energy_pct"])
    if verbose:
        for s, v in by_kind.items():
            print(f"[dvfs_by_arch] {s:12s} mean energy saving "
                  f"{np.mean(v):+.2f}%")
    save_artifact("dvfs_by_arch", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
