"""The paper's headline training claim, executed end-to-end (§5–6, §7–8).

Three measurements on the GPT-3-xl train step (seq 1024, batch 40), all
through the ``repro.dvfs`` facade:

1. **Kernel-level vs pass-level vs auto** — two :class:`DvfsSession`\\ s
   sharing one measurement campaign, one with the ``kernel-static``
   governor and one with ``pass-level``, both at the same relaxed-waste
   budget (tau = 0.6%, the paper's operating point) and *executed* over
   ``N_STEPS`` optimizer steps: per-phase clock replay, switch overhead
   charged, energy integrated against the auto-governor twin.
   Paper: kernel-level recovers 14.6% of training energy at 0.6% slowdown
   where pass-level recovers ~2%.
2. **DP transfer** — the single-device plan replayed under DP=2/4
   meshes (per-device batch 20/10) vs replanning each mesh from scratch.
3. **TP transfer** — the same plan replayed under TP=2/4 meshes
   (sharded kernels, roofline-remapped transfer) vs per-mesh replanning.
   Paper §7–8: the discovered frequencies translate across parallelism.

The full run also writes a repo-root ``BENCH_train.json`` perf anchor
(kernel/pass energy + time deltas), mirroring ``BENCH_serve.json``;
``make bench-smoke`` re-runs section 1 (``--smoke --check``) and fails if
the executed kernel-level plan regresses against that anchor.

Run:  PYTHONPATH=src python -m benchmarks.train_dvfs
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

from repro.configs import get_config, get_shape
from repro.core import Campaign, WastePolicy, build_workload, get_chip
from repro.dvfs import DvfsSession
from repro.launch.mesh import MeshSpec
from repro.parallel.plan_transfer import compare_transfer
from .common import save_artifact

ARCH = "gpt3-xl"
SHAPE = "paper_gpt3xl"
CHIP = "tpu-v5e"          # the µs-switch chip: per-kernel DVFS is realizable
TAU = 0.006               # paper's 0.6% slowdown operating point
N_STEPS = 10
N_REPS = 5
MESHES = (MeshSpec(dp=2), MeshSpec(dp=4), MeshSpec(tp=2), MeshSpec(tp=4))

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_train.json")


def _executed(session: DvfsSession, cfg, shape, table,
              n_steps: int = N_STEPS) -> Dict:
    """Plan with the session's governor against the shared table, then
    execute n_steps through the session executor."""
    session.plan_train(cfg, shape=shape, table=table)
    ex = session.train_executor()
    for s in range(n_steps):
        ex.on_step(s)
    session.close()
    return ex.summary()


def headline_section(n_steps: int = N_STEPS,
                     include_pass: bool = True) -> Dict:
    """Kernel-level (vs pass-level), executed through DvfsSession."""
    cfg = get_config(ARCH)
    shape = get_shape(SHAPE)
    chip = get_chip(CHIP)

    # one campaign; both governors plan against the same table
    kernels = build_workload(cfg, shape, include_optimizer=True)
    table = Campaign(chip, seed=0, n_reps=N_REPS).run(kernels)
    kernel_sess = DvfsSession(chip=chip, tau=TAU, n_reps=N_REPS)
    kernel = _executed(kernel_sess, cfg, shape, table, n_steps)
    out = {"cfg": cfg, "shape": shape, "chip": chip, "table": table,
           "kernel_sess": kernel_sess, "kernel": kernel}
    if include_pass:
        pass_sess = DvfsSession(chip=chip, tau=TAU, n_reps=N_REPS,
                                governor="pass-level")
        out["pass"] = _executed(pass_sess, cfg, shape, table, n_steps)
    return out


def main(verbose: bool = True) -> Dict:
    h = headline_section()
    cfg, shape, chip = h["cfg"], h["shape"], h["chip"]
    kernel, passl = h["kernel"], h["pass"]
    policy = WastePolicy(TAU)
    kernel_bundle = h["kernel_sess"].plan.to_train_bundle()

    transfer = [r.to_dict() for r in
                compare_transfer(kernel_bundle, cfg, chip, shape,
                                 list(MESHES), policy, n_reps=N_REPS)]
    max_vs_replan = max(abs(r["energy_vs_replan_pct"]) for r in transfer)

    out = {
        "arch": ARCH, "chip": CHIP, "tau": TAU, "n_steps": N_STEPS,
        "kernel_level": kernel["totals"],
        "kernel_phases": kernel["phases"],
        "pass_level": passl["totals"],
        "transfer": transfer,
        "max_transfer_vs_replan_pct": max_vs_replan,
        "kernel_beats_pass": kernel["totals"]["energy_pct"]
        < passl["totals"]["energy_pct"],
        "bundle_summary": kernel_bundle.summary(),
    }
    save_artifact("train_dvfs", out)

    # perf-trajectory anchor (repo root, mirrors BENCH_serve.json)
    kt, pt = kernel["totals"], passl["totals"]
    with open(BENCH_FILE, "w") as f:
        json.dump({
            "arch": ARCH, "chip": CHIP, "tau": TAU, "n_steps": N_STEPS,
            "energy_pct": kt["energy_pct"], "time_pct": kt["time_pct"],
            "pass_energy_pct": pt["energy_pct"],
            "max_transfer_vs_replan_pct": max_vs_replan,
        }, f, indent=1, default=float)
        f.write("\n")

    if verbose:
        print(f"[train_dvfs] {ARCH} on {CHIP}, tau={TAU}, "
              f"{N_STEPS} executed steps:")
        print(f"  auto        :   +0.00% time    +0.00% energy")
        print(f"  pass-level  : {pt['time_pct']:+8.2f}% time "
              f"{pt['energy_pct']:+8.2f}% energy "
              f"({pt['n_switches']} switches)")
        print(f"  kernel-level: {kt['time_pct']:+8.2f}% time "
              f"{kt['energy_pct']:+8.2f}% energy "
              f"({kt['n_switches']} switches; paper: -14.6% at +0.6%)")
        for name, row in kernel["phases"].items():
            print(f"    {name:4s}: time {row['time_pct']:+7.3f}%  "
                  f"energy {row['energy_pct']:+8.3f}%  "
                  f"switches/step {row['n_switches'] // N_STEPS}")
        print(f"  plan transfer (vs per-mesh replanning):")
        for r in transfer:
            print(f"    {r['mesh']:10s}: xfer {r['transfer_energy_pct']:+7.2f}% "
                  f"replan {r['replan_energy_pct']:+7.2f}% "
                  f"-> within {r['energy_vs_replan_pct']:+5.2f}% "
                  f"(remapped {r['n_remapped']}, repaired {r['n_repaired']})")
        print(f"  max |transfer - replan| = {max_vs_replan:.2f}% "
              f"(criterion: <= 2%)")
    return out


def smoke(check: bool = True, energy_tolerance_pp: float = 1.0) -> int:
    """Headline-only run (skips transfer); non-zero exit when the
    executed kernel-level plan regresses against ``BENCH_train.json``.

    Gates: energy_pct may not rise more than ``energy_tolerance_pp``
    percentage points above the anchor (deeper savings always pass), and
    executed time must stay within the tau budget (+ a small slack for
    phase-boundary switches, which the planner cannot see).
    """
    h = headline_section(n_steps=2, include_pass=False)
    kt = h["kernel"]["totals"]
    print(f"bench-smoke(train): kernel-level {kt['energy_pct']:+.2f}% "
          f"energy at {kt['time_pct']:+.3f}% time")
    if not check:
        return 0
    if not os.path.exists(BENCH_FILE):
        print(f"bench-smoke(train): no {os.path.basename(BENCH_FILE)} "
              f"baseline; run `python -m benchmarks.train_dvfs` first")
        return 1
    with open(BENCH_FILE) as f:
        base = json.load(f)
    ceil = base["energy_pct"] + energy_tolerance_pp
    budget = 100.0 * TAU + 0.1
    ok = kt["energy_pct"] <= ceil and kt["time_pct"] <= budget
    print(f"bench-smoke(train): energy {kt['energy_pct']:+.2f}% "
          f"(ceiling {ceil:+.2f}%), time {kt['time_pct']:+.3f}% "
          f"(budget {budget:+.3f}%) -> {'OK' if ok else 'REGRESSION'}")
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser(prog="benchmarks.train_dvfs")
    ap.add_argument("--smoke", action="store_true",
                    help="headline-only run (skips plan transfer)")
    ap.add_argument("--check", action="store_true",
                    help="with --smoke: fail on regression vs "
                         "BENCH_train.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(check=args.check))
    main()
