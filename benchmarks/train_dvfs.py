"""The paper's headline training claim, executed end-to-end (§5–6, §7–8).

Three measurements on the GPT-3-xl train step (seq 1024, batch 40):

1. **Kernel-level vs pass-level vs auto** — both planned at the same
   relaxed-waste budget (tau = 0.6%, the paper's operating point) and
   *executed* through :class:`~repro.runtime.dvfs_exec.TrainPhaseExecutor`
   over ``N_STEPS`` optimizer steps: per-phase clock replay, switch
   overhead charged, energy integrated against the auto-governor twin.
   Paper: kernel-level recovers 14.6% of training energy at 0.6% slowdown
   where pass-level recovers ~2%.
2. **DP transfer** — the single-device bundle replayed under DP=2/4
   meshes (per-device batch 20/10) vs replanning each mesh from scratch.
3. **TP transfer** — the same bundle replayed under TP=2/4 meshes
   (sharded kernels, roofline-remapped transfer) vs per-mesh replanning.
   Paper §7–8: the discovered frequencies translate across parallelism.

Run:  PYTHONPATH=src python -m benchmarks.train_dvfs
"""
from __future__ import annotations

from typing import Dict

from repro.configs import get_config, get_shape
from repro.core import (Campaign, WastePolicy, build_workload, get_chip,
                        pass_level_plan, plan_train_bundle)
from repro.launch.mesh import MeshSpec
from repro.parallel.plan_transfer import compare_transfer
from repro.runtime import TrainPhaseExecutor
from .common import save_artifact

ARCH = "gpt3-xl"
SHAPE = "paper_gpt3xl"
CHIP = "tpu-v5e"          # the µs-switch chip: per-kernel DVFS is realizable
TAU = 0.006               # paper's 0.6% slowdown operating point
N_STEPS = 10
N_REPS = 5
MESHES = (MeshSpec(dp=2), MeshSpec(dp=4), MeshSpec(tp=2), MeshSpec(tp=4))


def _execute(bundle, chip, n_steps: int) -> Dict:
    ex = TrainPhaseExecutor(bundle, chip)
    for s in range(n_steps):
        ex.on_step(s)
    ex.finish()
    return ex.summary()


def main(verbose: bool = True) -> Dict:
    cfg = get_config(ARCH)
    shape = get_shape(SHAPE)
    chip = get_chip(CHIP)
    policy = WastePolicy(TAU)

    # one campaign; both granularities plan against the same table
    kernels = build_workload(cfg, shape, include_optimizer=True)
    table = Campaign(chip, seed=0, n_reps=N_REPS).run(kernels)
    kernel_bundle = plan_train_bundle(cfg, chip, shape=shape,
                                      policy=policy, table=table)
    pass_bundle = plan_train_bundle(cfg, chip, shape=shape, policy=policy,
                                    table=table, planner=pass_level_plan)
    kernel = _execute(kernel_bundle, chip, N_STEPS)
    passl = _execute(pass_bundle, chip, N_STEPS)

    transfer = [r.to_dict() for r in
                compare_transfer(kernel_bundle, cfg, chip, shape,
                                 list(MESHES), policy, n_reps=N_REPS)]
    max_vs_replan = max(abs(r["energy_vs_replan_pct"]) for r in transfer)

    out = {
        "arch": ARCH, "chip": CHIP, "tau": TAU, "n_steps": N_STEPS,
        "kernel_level": kernel["totals"],
        "kernel_phases": kernel["phases"],
        "pass_level": passl["totals"],
        "transfer": transfer,
        "max_transfer_vs_replan_pct": max_vs_replan,
        "kernel_beats_pass": kernel["totals"]["energy_pct"]
        < passl["totals"]["energy_pct"],
        "bundle_summary": kernel_bundle.summary(),
    }
    save_artifact("train_dvfs", out)

    if verbose:
        kt, pt = kernel["totals"], passl["totals"]
        print(f"[train_dvfs] {ARCH} on {CHIP}, tau={TAU}, "
              f"{N_STEPS} executed steps:")
        print(f"  auto        :   +0.00% time    +0.00% energy")
        print(f"  pass-level  : {pt['time_pct']:+8.2f}% time "
              f"{pt['energy_pct']:+8.2f}% energy "
              f"({pt['n_switches']} switches)")
        print(f"  kernel-level: {kt['time_pct']:+8.2f}% time "
              f"{kt['energy_pct']:+8.2f}% energy "
              f"({kt['n_switches']} switches; paper: -14.6% at +0.6%)")
        for name, row in kernel["phases"].items():
            print(f"    {name:4s}: time {row['time_pct']:+7.3f}%  "
                  f"energy {row['energy_pct']:+8.3f}%  "
                  f"switches/step {row['n_switches'] // N_STEPS}")
        print(f"  plan transfer (vs per-mesh replanning):")
        for r in transfer:
            print(f"    {r['mesh']:10s}: xfer {r['transfer_energy_pct']:+7.2f}% "
                  f"replan {r['replan_energy_pct']:+7.2f}% "
                  f"-> within {r['energy_vs_replan_pct']:+5.2f}% "
                  f"(remapped {r['n_remapped']}, repaired {r['n_repaired']})")
        print(f"  max |transfer - replan| = {max_vs_replan:.2f}% "
              f"(criterion: <= 2%)")
    return out


if __name__ == "__main__":
    main()
