"""Paper Fig. 7 (validation aspect) + §6 'Validation': re-measure the
discovered best clocks vs auto 10x; selection bias makes realized savings
smaller than discovered ones."""
from __future__ import annotations

import numpy as np

from .common import gpt3xl_campaign, save_artifact, solve


def main(verbose: bool = True, n_rounds: int = 10):
    camp, table = gpt3xl_campaign()
    plan = solve(table, "kernel-static")
    disc_t, disc_e = plan.time_pct, plan.energy_pct
    dts, des = [], []
    for _ in range(n_rounds):
        tp, ep, ta, ea = camp.remeasure(table, plan.choice)
        dts.append(100 * (tp / ta - 1))
        des.append(100 * (ep / ea - 1))
    out = {
        "discovered_time_pct": disc_t, "discovered_energy_pct": disc_e,
        "realized_time_pct_mean": float(np.mean(dts)),
        "realized_time_pct_min": float(np.min(dts)),
        "realized_time_pct_max": float(np.max(dts)),
        "realized_energy_pct_mean": float(np.mean(des)),
        "realized_energy_pct_min": float(np.min(des)),
        "realized_energy_pct_max": float(np.max(des)),
        "selection_bias_pp": float(np.mean(des) - disc_e),
    }
    if verbose:
        print(f"[validation] discovered t={disc_t:+.2f}% e={disc_e:+.2f}%")
        print(f"[validation] realized  t={out['realized_time_pct_mean']:+.2f}% "
              f"[{out['realized_time_pct_min']:+.2f},{out['realized_time_pct_max']:+.2f}]  "
              f"e={out['realized_energy_pct_mean']:+.2f}% "
              f"[{out['realized_energy_pct_min']:+.2f},"
              f"{out['realized_energy_pct_max']:+.2f}]"
              f"  (paper: +0.6% / -14.6%)")
    save_artifact("validation", out)
    return out


if __name__ == "__main__":
    main()
