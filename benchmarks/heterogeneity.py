"""Paper §9 'GPU heterogeneity': rerun the fine-grained campaign on an
A4000-class chip (narrower V/F range) — savings shrink, clock *types*
transfer."""
from __future__ import annotations

from .common import gpt3xl_campaign, save_artifact, solve


def main(verbose: bool = True):
    out = {}
    for chip in ("rtx3080ti", "a4000"):
        camp, table = gpt3xl_campaign(chip_name=chip)
        g = solve(table, "kernel-static")
        e = solve(table, "edp", level="global")
        out[chip] = {"waste": g.summary(), "edp": e.summary()}
        if verbose:
            print(f"[heterogeneity] {chip:10s} strict-waste "
                  f"e={g.energy_pct:+6.2f}% (t={g.time_pct:+5.2f}%) | "
                  f"EDP e={e.energy_pct:+6.2f}% (t={e.time_pct:+6.2f}%)")
    if verbose:
        print("[heterogeneity] paper: A4000 -9.56% @ 0% (waste), "
              "-8.28% @ +2.33%... (EDP)")
    save_artifact("heterogeneity", out)
    return out


if __name__ == "__main__":
    main()
