"""Paper Fig. 5: absolute time & energy per kernel, auto vs min/max over
all clock configurations."""
from __future__ import annotations

import numpy as np

from .common import gpt3xl_campaign, save_artifact


def main(verbose: bool = True):
    camp, table = gpt3xl_campaign()
    rows = []
    for i, k in enumerate(table.kernels):
        rows.append({
            "kernel": f"#{i} {k.name}", "kind": k.kind,
            "invocations": k.invocations,
            "auto_time_s": float(table.time[i, table.auto_idx]),
            "auto_energy_j": float(table.energy[i, table.auto_idx]),
            "min_time_s": float(table.time[i].min()),
            "max_time_s": float(table.time[i].max()),
            "min_energy_j": float(table.energy[i].min()),
            "max_energy_j": float(table.energy[i].max()),
        })
    out = {"kernels": rows, "n_kernels": len(rows)}
    if verbose:
        spread_t = max(r["max_time_s"] / r["min_time_s"] for r in rows)
        spread_e = max(r["max_energy_j"] / r["min_energy_j"] for r in rows)
        print(f"[kernel_overview] {len(rows)} kernels; max time spread "
              f"{spread_t:.1f}x, max energy spread {spread_e:.1f}x "
              f"across clock configs")
    save_artifact("kernel_overview", out)
    return out


if __name__ == "__main__":
    main()
