"""Paper Table 2: time/energy totals for {coarse, fine} x {local, global}
x {waste, EDP} — every cell produced through the repro.dvfs governor
registry (one facade, seven policy variants)."""
from __future__ import annotations

from .common import gpt3xl_campaign, save_artifact, solve

PAPER = {  # the paper's Table 2, for side-by-side reporting
    "pass-local": (-0.20, -1.98), "pass-global": (-0.10, -2.07),
    "kernel-local": (-1.78, -11.54), "kernel-global": (+0.00, -15.64),
    "edp-local": (+10.03, -27.34), "edp-global": (+10.28, -27.52),
    "edp-pass": (+10.21, -25.42),
}


def main(verbose: bool = True):
    camp, table = gpt3xl_campaign()
    plans = [
        solve(table, "pass-level", aggregation="local"),
        solve(table, "pass-level", aggregation="global"),
        solve(table, "kernel-static", aggregation="local"),
        solve(table, "kernel-static", aggregation="global"),
        solve(table, "edp", level="pass"),
        solve(table, "edp", level="local"),
        solve(table, "edp", level="global"),
    ]
    rows = []
    for p in plans:
        s = p.summary()
        ref = PAPER.get(s["plan"])
        s["paper_time_pct"], s["paper_energy_pct"] = \
            (ref if ref else (None, None))
        rows.append(s)
        if verbose:
            ps = f" (paper {ref[0]:+.2f}/{ref[1]:+.2f})" if ref else ""
            print(f"[totals] {s['plan']:14s} t={s['time_pct']:+7.2f}% "
                  f"e={s['energy_pct']:+7.2f}%{ps}")
    save_artifact("totals", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
