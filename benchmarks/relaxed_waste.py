"""Paper Fig. 6: energy saved vs tolerated time-increase threshold, local
vs global aggregation (incl. the strict tau=0 point and the energy-only
asymptote)."""
from __future__ import annotations

from .common import gpt3xl_campaign, save_artifact, solve

TAUS = (0.0, 0.005, 0.01, 0.02, 0.05, 0.10, 0.20, 0.30, 0.50, 1.0)


def main(verbose: bool = True):
    camp, table = gpt3xl_campaign()
    rows = []
    for tau in TAUS:
        g = solve(table, "kernel-static", tau=tau)
        l = solve(table, "kernel-static", tau=tau, aggregation="local")
        rows.append({"tau_pct": 100 * tau,
                     "global_time_pct": g.time_pct,
                     "global_energy_pct": g.energy_pct,
                     "local_time_pct": l.time_pct,
                     "local_energy_pct": l.energy_pct})
        if verbose:
            print(f"[relaxed_waste] tau={100*tau:5.1f}%  "
                  f"global e={g.energy_pct:+7.2f}% (t={g.time_pct:+6.2f}%)"
                  f"  local e={l.energy_pct:+7.2f}% "
                  f"(t={l.time_pct:+6.2f}%)")
    # energy-only asymptote (tau -> inf)
    e_only = solve(table, "kernel-static", tau=1e9)
    rows.append({"tau_pct": float("inf"),
                 "global_time_pct": e_only.time_pct,
                 "global_energy_pct": e_only.energy_pct})
    if verbose:
        print(f"[relaxed_waste] energy-only optimum: "
              f"e={e_only.energy_pct:+.2f}% at t={e_only.time_pct:+.2f}% "
              f"(paper: -36.9% at +84%)")
    save_artifact("relaxed_waste", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
