"""Paper Figs. 3-4 + §5: pass-level (coarse-grained) compute-waste analysis.

For the forward and backward passes, sweep every (mem, core) clock pair and
report the (time%, energy%) scatter vs the auto baseline, the waste-square
membership, and the per-pass best clocks under strict waste.
"""
from __future__ import annotations

import numpy as np

from repro.core.planner import _pass_tables
from .common import gpt3xl_campaign, save_artifact, solve


def main(verbose: bool = True):
    camp, table = gpt3xl_campaign()
    groups = _pass_tables(table)
    auto = table.auto_idx
    out = {}
    for phase in ("fwd", "bwd"):
        T, E = groups[phase]
        dt = 100.0 * (T / T[auto] - 1.0)
        de = 100.0 * (E / E[auto] - 1.0)
        in_square = (dt <= 0.0 + 1e-9) & (de <= 0.0)
        best = None
        if in_square.any():
            idx = np.where(in_square)[0]
            best = int(idx[np.argmin(de[idx])])
        rows = []
        for j, p in enumerate(table.pairs):
            rows.append({"mem": p.mem, "core": p.core,
                         "time_pct": round(float(dt[j]), 3),
                         "energy_pct": round(float(de[j]), 3),
                         "waste_square": bool(in_square[j])})
        out[phase] = {
            "n_in_square": int(in_square.sum()),
            "best": rows[best] if best is not None else None,
            "scatter": rows,
        }
        if verbose:
            b = out[phase]["best"]
            print(f"[pass_level] {phase}: {out[phase]['n_in_square']} "
                  f"configs in waste square; best: "
                  f"{b if b is None else (b['mem'], b['core'])} "
                  f"t={b['time_pct'] if b else '--'}% "
                  f"e={b['energy_pct'] if b else '--'}%")
    plan = solve(table, "pass-level", aggregation="global")
    out["strict_totals"] = plan.summary()
    if verbose:
        s = plan.summary()
        print(f"[pass_level] strict waste (global): "
              f"t={s['time_pct']}% e={s['energy_pct']}%  "
              f"(paper: -0.10% / -2.07%)")
    save_artifact("pass_level", out)
    return out


if __name__ == "__main__":
    main()
