"""Paper Fig. 7 / §7: data parallelism — apply the batch-40 discovered
clocks to smaller per-GPU batches and measure transfer."""
from __future__ import annotations

import numpy as np

from .common import gpt3xl_campaign, save_artifact, solve

BATCHES = (40, 20, 10, 8, 4, 2, 1)


def main(verbose: bool = True):
    camp0, table0 = gpt3xl_campaign(batch=40)
    plan = solve(table0, "kernel-static")
    rows = []
    for b in BATCHES:
        camp, table = gpt3xl_campaign(batch=b, seed=100 + b)
        # same kernel list/order -> apply the batch-40 choice directly
        t, e = table.totals(plan.choice)
        tb, eb = table.baseline_totals()
        rows.append({"batch": b,
                     "time_pct": 100 * (t / tb - 1),
                     "energy_pct": 100 * (e / eb - 1)})
        if verbose:
            r = rows[-1]
            print(f"[data_parallel] batch {b:3d}: t={r['time_pct']:+6.2f}% "
                  f"e={r['energy_pct']:+7.2f}%")
    spread_t = max(r["time_pct"] for r in rows) - \
        min(r["time_pct"] for r in rows)
    spread_e = max(r["energy_pct"] for r in rows) - \
        min(r["energy_pct"] for r in rows)
    out = {"rows": rows, "time_spread_pp": spread_t,
           "energy_spread_pp": spread_e}
    if verbose:
        print(f"[data_parallel] transfer spread: {spread_t:.2f} pp time, "
              f"{spread_e:.2f} pp energy (paper: ~2.4 pp / ~0.7 pp)")
    save_artifact("data_parallel", out)
    return out


if __name__ == "__main__":
    main()
