"""Prefix-cache serving: radix/CoW page sharing under a multi-tenant
Zipf trace, cache-aware DVFS re-planning, and cache-affinity routing.

Three claims, measured on one seeded tenant-tagged trace (Zipf-shared
prefix templates, per-tenant SLO classes) replayed across a small fleet
in modeled time — the same accounting substrate as every other
benchmark, with each replica's radix tree splicing cached prompt pages
at admission and billing only the uncached suffix fraction of each
prefill:

1. **Cache** — at >= 50% request prefix-hit rate, turning the radix
   cache on beats cache-off on tokens/sec *and* median TTFT (and, by
   construction, on joules/token: skipped prefill work is skipped
   energy).
2. **Re-planning (claim 15)** — prefix hits tilt the executed phase mix
   decode-ward and shift the decode-bucket occupancy mix away from what
   the static plan assumed.  The online governor's TV-distance drift
   detector catches this and re-plans from cached measurement tables;
   the claim anchors the *recovered fraction* of the stale-plan energy
   gap: ``(J_static - J_online) / (J_static - J_oracle)``, where the
   oracle fleet starts pre-re-planned on the mix a probe run observed.
3. **Routing** — with page pools too small for every replica to cache
   every tenant's templates, cache-affinity routing (prefill term scaled
   by each candidate's predicted uncached-suffix fraction) beats
   energy-slo routing on joules/token at equal-or-better p99 TTFT:
   template traffic concentrates where its prefix is warm instead of
   re-prefilling everywhere.

Merges ``prefix_*`` anchors into the repo-root ``BENCH_serve.json``
(legacy ``serve_continuous`` anchors are preserved byte-for-byte);
``make bench-smoke`` re-runs all three claims and fails on a lost claim
or a >10% joules-per-token regression, naming the offending anchor.

Run:  PYTHONPATH=src python -m benchmarks.serve_prefix
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

ARCH = "llama3.2-1b"
N_REQUESTS = 200
#: saturating arrival rate: prefill work bounds the makespan, so cached
#: prefixes buy real throughput, not just TTFT
RATE_RPS = 150.0
SEED = 0
N_REPLICAS = 2

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")

#: tpu-speed TTFT operating point (matches serve_fleet's TPU_ROUTER)
ROUTER_KW = dict(slo_ttft_s=0.08, slo_weight=60.0, slack=0.3)
#: routing section: 3 replicas over pools sized so one replica cannot
#: hold every tenant's templates plus its live slots — the regime where
#: locality (not raw capacity) decides hit rates — with longer template
#: prefixes (a bigger shared working set) and a TTFT target loose
#: enough that both policies pack for energy
AFFINITY_REPLICAS = 3
AFFINITY_POOL_PAGES = 40
AFFINITY_TEMPLATE_LENS = (40, 56, 72)
AFFINITY_ROUTER_KW = dict(slo_ttft_s=0.12, slo_weight=60.0, slack=0.3)


def _trace(n_requests: int = N_REQUESTS, **kw):
    """Seeded multi-tenant Zipf trace: 4 tenants x 2 templates, suffix
    lengths that leave a shared mid-page tail (CoW splices fire)."""
    from repro.fleet import generate_tenant_trace
    return generate_tenant_trace("poisson", n_requests=n_requests,
                                 rate_rps=RATE_RPS, seed=SEED, **kw)


def _fleet(specs, router_name: str = "energy-slo", *,
           prefix_cache: bool = True,
           pool_pages: Optional[int] = None,
           rkw: Optional[Dict] = None):
    from repro.configs import REGISTRY
    from repro.fleet import build_fleet, router
    r = router(router_name, **(rkw or ROUTER_KW))
    return build_fleet(specs, REGISTRY[ARCH], router=r, n_reps=3,
                       seed=SEED, prefix_cache=prefix_cache,
                       pool_pages=pool_pages)


def _row(rep: Dict) -> Dict:
    row = {"joules_per_token": rep["joules_per_token"],
           "tokens_per_s": rep["tokens"] / rep["makespan_s"],
           "energy_j": rep["energy_j"],
           "ttft_p50_s": rep["ttft_p50_s"],
           "ttft_p99_s": rep["ttft_p99_s"],
           "makespan_s": rep["makespan_s"],
           "n_completed": rep["n_completed"]}
    cache = _cache_stats(rep)
    if cache is not None:
        row["cache"] = cache
    return row


def _cache_stats(rep: Dict) -> Optional[Dict]:
    """Aggregate per-replica radix/pool counters into fleet totals."""
    books = [b for b in rep["replicas"] if b.get("prefix_cache")]
    if not books:
        return None
    tot = {"hits": 0, "misses": 0, "hit_tokens": 0, "lookup_tokens": 0,
           "nodes": 0, "cow_copies": 0, "evictions": 0,
           "cached_prompt_tokens": 0}
    for b in books:
        pc = b["prefix_cache"]
        for k in ("hits", "misses", "hit_tokens", "lookup_tokens",
                  "nodes"):
            tot[k] += pc[k]
        tot["cow_copies"] += b["pool"]["cow_copies"]
        tot["evictions"] += b["pool"]["evictions"]
        tot["cached_prompt_tokens"] += b.get("cached_prompt_tokens", 0)
    n = tot["hits"] + tot["misses"]
    tot["hit_rate"] = tot["hits"] / n if n else 0.0
    tot["token_hit_rate"] = tot["hit_tokens"] / tot["lookup_tokens"] \
        if tot["lookup_tokens"] else 0.0
    return tot


def cache_section(n_requests: int = N_REQUESTS) -> Dict:
    """Claim 1: cache on vs off, same trace / fleet / router."""
    from repro.fleet import ReplicaSpec
    trace = _trace(n_requests)
    specs = [ReplicaSpec()] * N_REPLICAS
    off = _fleet(specs, prefix_cache=False).serve(trace)
    on = _fleet(specs, prefix_cache=True).serve(trace)
    out: Dict = {"trace": trace.meta, "cache_off": _row(off),
                 "cache_on": _row(on)}
    cache = out["cache_on"]["cache"]
    out["hit_rate"] = cache["hit_rate"]
    out["token_hit_rate"] = cache["token_hit_rate"]
    out["tokens_per_s_speedup"] = (out["cache_on"]["tokens_per_s"]
                                   / out["cache_off"]["tokens_per_s"])
    out["j_per_tok_vs_off_pct"] = 100.0 * (
        out["cache_on"]["joules_per_token"]
        / out["cache_off"]["joules_per_token"] - 1.0)
    out["cache_wins"] = (
        cache["hit_rate"] >= 0.5
        and out["cache_on"]["tokens_per_s"]
        > out["cache_off"]["tokens_per_s"]
        and out["cache_on"]["ttft_p50_s"] < out["cache_off"]["ttft_p50_s"]
        and out["cache_on"]["n_completed"] == n_requests)
    return out


def _observed_mixes(fleet) -> Dict[str, Dict[int, float]]:
    """Per-replica decode-bucket mixes an online probe run observed."""
    mixes = {}
    for r in fleet.replicas:
        mix = getattr(r.governor, "observed_mix", lambda: {})()
        if mix:
            mixes[r.name] = mix
    return mixes


def replan_section(n_requests: int = N_REQUESTS) -> Dict:
    """Claim 2 (docs claim 15): static vs online vs oracle-warm plans,
    all with the prefix cache on.

    The template plans are campaigned for the *cache-off* phase mix;
    prefix hits shrink prefills and shift decode occupancy, so the
    static fleet executes a stale plan for the whole trace.  The online
    fleet detects the mix drift mid-run and re-plans; the oracle fleet
    starts already re-planned on the mix the online probe observed —
    the best the re-planner could possibly do.  The claim is the
    recovered fraction of the stale-plan energy gap."""
    from repro.fleet import ReplicaSpec
    trace = _trace(n_requests)
    static = _fleet([ReplicaSpec(governor="kernel-static")] * N_REPLICAS
                    ).serve(trace)
    probe = _fleet([ReplicaSpec()] * N_REPLICAS)
    online = probe.serve(trace)
    mixes = _observed_mixes(probe)
    fallback = next(iter(mixes.values()), None)
    oracle_fleet = _fleet([ReplicaSpec()] * N_REPLICAS)
    for r in oracle_fleet.replicas:
        mix = mixes.get(r.name, fallback)
        if mix:
            r.governor.replan(mix, ["oracle-warm"])
    oracle = oracle_fleet.serve(trace)

    n_replans = sum(r.governor.revision - 1 for r in probe.replicas)
    js, jo, jor = (static["joules_per_token"],
                   online["joules_per_token"],
                   oracle["joules_per_token"])
    gap = js - jor
    out = {"trace": trace.meta,
           "static": _row(static), "online": _row(online),
           "oracle": _row(oracle),
           "n_online_replans": n_replans,
           "stale_gap_j_per_tok": gap,
           "recovered_frac": (js - jo) / gap if gap > 0 else 0.0}
    out["replan_recovers"] = (
        gap > 0 and out["recovered_frac"] > 0.25
        and out["online"]["n_completed"] == n_requests)
    return out


def routing_section(n_requests: int = N_REQUESTS) -> Dict:
    """Claim 3: cache-affinity vs energy-slo routing on capacity-
    constrained pools (no replica can cache the whole template working
    set — placement decides who stays warm)."""
    from repro.fleet import ReplicaSpec
    trace = _trace(n_requests, template_lens=AFFINITY_TEMPLATE_LENS)
    specs = [ReplicaSpec()] * AFFINITY_REPLICAS
    es = _fleet(specs, "energy-slo", pool_pages=AFFINITY_POOL_PAGES,
                rkw=AFFINITY_ROUTER_KW).serve(trace)
    aff = _fleet(specs, "cache-affinity",
                 pool_pages=AFFINITY_POOL_PAGES,
                 rkw=AFFINITY_ROUTER_KW).serve(trace)
    out: Dict = {"trace": trace.meta, "pool_pages": AFFINITY_POOL_PAGES,
                 "energy_slo": _row(es), "cache_affinity": _row(aff)}
    out["j_per_tok_vs_energy_slo_pct"] = 100.0 * (
        out["cache_affinity"]["joules_per_token"]
        / out["energy_slo"]["joules_per_token"] - 1.0)
    out["affinity_wins"] = (
        out["cache_affinity"]["joules_per_token"]
        < out["energy_slo"]["joules_per_token"]
        and out["cache_affinity"]["ttft_p99_s"]
        <= out["energy_slo"]["ttft_p99_s"]
        and out["cache_affinity"]["n_completed"] == n_requests)
    return out


def _merge_bench_file(new_keys: Dict) -> None:
    """Append/update ``prefix_*`` anchors without disturbing the legacy
    ``serve_continuous`` anchors (dict insertion order keeps their bytes
    identical through the rewrite)."""
    payload: Dict = {}
    if os.path.exists(BENCH_FILE):
        with open(BENCH_FILE) as f:
            payload = json.load(f)
    payload.update(new_keys)
    with open(BENCH_FILE, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")


def _anchors(cache: Dict, replan: Dict, routing: Dict) -> Dict:
    return {
        "prefix_hit_rate": cache["hit_rate"],
        "prefix_token_hit_rate": cache["token_hit_rate"],
        "prefix_cache_on_j_per_tok":
            cache["cache_on"]["joules_per_token"],
        "prefix_cache_off_j_per_tok":
            cache["cache_off"]["joules_per_token"],
        "prefix_cache_on_tokens_per_s":
            cache["cache_on"]["tokens_per_s"],
        "prefix_cache_off_tokens_per_s":
            cache["cache_off"]["tokens_per_s"],
        "prefix_cache_on_ttft_p50_s": cache["cache_on"]["ttft_p50_s"],
        "prefix_cache_off_ttft_p50_s": cache["cache_off"]["ttft_p50_s"],
        "prefix_cache_wins": cache["cache_wins"],
        "prefix_static_j_per_tok": replan["static"]["joules_per_token"],
        "prefix_online_j_per_tok": replan["online"]["joules_per_token"],
        "prefix_oracle_j_per_tok": replan["oracle"]["joules_per_token"],
        "prefix_replan_recovered_frac": replan["recovered_frac"],
        "prefix_n_online_replans": replan["n_online_replans"],
        "prefix_replan_recovers": replan["replan_recovers"],
        "prefix_affinity_j_per_tok":
            routing["cache_affinity"]["joules_per_token"],
        "prefix_energyslo_j_per_tok":
            routing["energy_slo"]["joules_per_token"],
        "prefix_affinity_ttft_p99_s":
            routing["cache_affinity"]["ttft_p99_s"],
        "prefix_energyslo_ttft_p99_s":
            routing["energy_slo"]["ttft_p99_s"],
        "prefix_affinity_wins": routing["affinity_wins"],
    }


def _print_sections(cache: Dict, replan: Dict, routing: Dict) -> None:
    on, off = cache["cache_on"], cache["cache_off"]
    print(f"prefix cache ({N_REQUESTS} requests, {N_REPLICAS}x tpu-v5e, "
          f"zipf tenant trace @ {RATE_RPS:.0f} rps):")
    print(f"  cache off : {off['joules_per_token']:.4f} J/tok, "
          f"{off['tokens_per_s']:.0f} tok/s, TTFT p50/p99 "
          f"{off['ttft_p50_s']*1e3:.1f}/{off['ttft_p99_s']*1e3:.0f} ms")
    c = on["cache"]
    print(f"  cache on  : {on['joules_per_token']:.4f} J/tok "
          f"({cache['j_per_tok_vs_off_pct']:+.1f}%), "
          f"{on['tokens_per_s']:.0f} tok/s "
          f"({cache['tokens_per_s_speedup']:.2f}x), TTFT p50/p99 "
          f"{on['ttft_p50_s']*1e3:.1f}/{on['ttft_p99_s']*1e3:.0f} ms "
          f"[hit {cache['hit_rate']:.0%} req / "
          f"{cache['token_hit_rate']:.0%} tok, {c['cow_copies']} CoW, "
          f"{c['evictions']} evictions]")
    print(f"  >=50% hits + faster + lower TTFT "
          f"-> {'OK' if cache['cache_wins'] else 'LOST'}")
    print("prefix-aware re-planning (claim 15, cache on everywhere):")
    for k in ("static", "online", "oracle"):
        row = replan[k]
        print(f"  {k:7s}: {row['joules_per_token']:.4f} J/tok, "
              f"makespan {row['makespan_s']:.2f}s")
    print(f"  online recovers {replan['recovered_frac']:.0%} of the "
          f"stale-plan gap ({replan['stale_gap_j_per_tok']:.4f} J/tok) "
          f"in {replan['n_online_replans']} re-plans "
          f"-> {'OK' if replan['replan_recovers'] else 'LOST'}")
    es, aff = routing["energy_slo"], routing["cache_affinity"]
    print(f"cache-affinity routing ({AFFINITY_POOL_PAGES}-page pools):")
    print(f"  energy-slo    : {es['joules_per_token']:.4f} J/tok, "
          f"TTFT p99 {es['ttft_p99_s']*1e3:.0f} ms, "
          f"hit {es['cache']['hit_rate']:.0%}")
    print(f"  cache-affinity: {aff['joules_per_token']:.4f} J/tok "
          f"({routing['j_per_tok_vs_energy_slo_pct']:+.1f}%), "
          f"TTFT p99 {aff['ttft_p99_s']*1e3:.0f} ms, "
          f"hit {aff['cache']['hit_rate']:.0%} "
          f"-> {'OK' if routing['affinity_wins'] else 'LOST'}")


def main(verbose: bool = True) -> Dict:
    from .common import save_artifact

    cache = cache_section()
    replan = replan_section()
    routing = routing_section()
    out = {"arch": ARCH, "n_requests": N_REQUESTS, "cache": cache,
           "replan": replan, "routing": routing}
    save_artifact("serve_prefix", out)
    _merge_bench_file(_anchors(cache, replan, routing))
    if verbose:
        _print_sections(cache, replan, routing)
    return out


def smoke(check: bool = True, tolerance: float = 0.10) -> int:
    """Re-run the three prefix-cache claims (already benchmark scale);
    non-zero exit on a lost claim or a >tolerance joules-per-token
    regression vs the checked-in ``BENCH_serve.json`` anchors (the
    breach message names the offending anchor)."""
    cache = cache_section()
    replan = replan_section()
    routing = routing_section()
    print(f"bench-smoke(prefix): hit {cache['hit_rate']:.0%}, cache "
          f"{cache['j_per_tok_vs_off_pct']:+.1f}% J/tok vs off, replan "
          f"recovers {replan['recovered_frac']:.0%}, affinity "
          f"{routing['j_per_tok_vs_energy_slo_pct']:+.1f}% vs "
          f"energy-slo")
    claims_ok = (cache["cache_wins"] and replan["replan_recovers"]
                 and routing["affinity_wins"])
    if not claims_ok:
        print(f"bench-smoke(prefix): LOST CLAIM "
              f"(cache={cache['cache_wins']}, "
              f"replan={replan['replan_recovers']}, "
              f"affinity={routing['affinity_wins']})")
        return 1
    if not check:
        return 0
    if not os.path.exists(BENCH_FILE):
        print(f"bench-smoke(prefix): no {os.path.basename(BENCH_FILE)} "
              f"baseline; run `python -m benchmarks.serve_prefix` first")
        return 1
    with open(BENCH_FILE) as f:
        base = json.load(f)
    gates = (
        ("prefix_cache_on_j_per_tok",
         cache["cache_on"]["joules_per_token"]),
        ("prefix_online_j_per_tok",
         replan["online"]["joules_per_token"]),
        ("prefix_affinity_j_per_tok",
         routing["cache_affinity"]["joules_per_token"]),
    )
    for anchor, measured in gates:
        if anchor not in base:
            continue
        ceil = base[anchor] * (1.0 + tolerance)
        ok = measured <= ceil
        print(f"bench-smoke(prefix): {anchor} {measured:.4f} J/tok vs "
              f"ceiling {ceil:.4f} ({tolerance:.0%} over "
              f"{base[anchor]:.4f}) -> "
              f"{'OK' if ok else 'REGRESSION'}")
        if not ok:
            return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(prog="benchmarks.serve_prefix")
    ap.add_argument("--smoke", action="store_true",
                    help="re-run the three claims and exit non-zero on "
                         "a lost claim")
    ap.add_argument("--check", action="store_true",
                    help="with --smoke: fail on >10%% joules-per-token "
                         "regression vs BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(check=args.check))
    main()
