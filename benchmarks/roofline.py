"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run artifacts (trip-corrected HLO analysis).

  compute_s    = flops_per_device / peak_flops
  memory_s     = hbm_bytes_per_device / hbm_bw
  collective_s = collective_bytes_per_device / ici_bw

plus MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) and the useful-
compute ratio MODEL_FLOPS / HLO_FLOPS.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config, get_shape
from repro.hw import tpu
from .common import save_artifact

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    total, active = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.tokens
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * shape.tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def load_records():
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def analyze(rec):
    n_dev = rec["n_devices"]
    ha = rec["hlo_analysis"]
    flops_dev = ha["flops_per_device"]
    hbm_dev = ha["hbm_bytes_per_device"]
    coll_dev = rec["collectives"]["total_bytes"]
    terms = {
        "compute_s": flops_dev / tpu.PEAK_FLOPS_BF16,
        "memory_s": hbm_dev / tpu.HBM_BW,
        "collective_s": coll_dev / tpu.ICI_BW_PER_LINK,
    }
    # companion memory estimate from the analytic workload model (the HLO
    # figure is an upper bound: CPU-backend fusion materializes elementwise
    # chains a TPU compilation would fuse)
    try:
        from repro.core import build_workload, workload_totals
        ks = build_workload(get_config(rec["arch"]),
                            get_shape(rec["shape"]), tp=16, dp=16)
        _, h_model, _ = workload_totals(ks)
        mem_model = (h_model * (256.0 / n_dev)) / tpu.HBM_BW
    except Exception:
        mem_model = 0.0
    dominant = max(terms, key=terms.get)
    bound_time = max(terms.values())
    terms["memory_model_s"] = mem_model
    mf = model_flops(rec["arch"], rec["shape"])
    mf_dev = mf / n_dev
    useful_ratio = mf_dev / flops_dev if flops_dev else 0.0
    # roofline fraction: useful-model-compute time over the bound
    roofline_frac = (mf_dev / tpu.PEAK_FLOPS_BF16) / bound_time \
        if bound_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind"), "gib_per_device": rec.get("gib_per_device"),
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
    }


def main(verbose: bool = True):
    rows = []
    for rec in load_records():
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                rows.append({"arch": rec["arch"], "shape": rec["shape"],
                             "mesh": rec["mesh"], "status": "skipped"})
            else:
                rows.append({"arch": rec.get("arch"),
                             "shape": rec.get("shape"),
                             "mesh": rec.get("mesh"), "status": "error"})
            continue
        r = analyze(rec)
        r["status"] = "ok"
        rows.append(r)
        if verbose:
            print(f"[roofline] {r['arch']:24s} {r['shape']:12s} "
                  f"{r['mesh']:7s} C={r['compute_s']:9.2e}s "
                  f"M={r['memory_s']:9.2e}s X={r['collective_s']:9.2e}s "
                  f"dom={r['dominant'][:4]:4s} "
                  f"useful={r['useful_flops_ratio']:5.2f} "
                  f"roofline={r['roofline_fraction']:5.2f}")
    save_artifact("roofline", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
