"""Paper Table 1: optimal clocks + time/energy delta per kernel under
globally-aggregated strict waste."""
from __future__ import annotations

from .common import gpt3xl_campaign, save_artifact, solve


def main(verbose: bool = True):
    camp, table = gpt3xl_campaign()
    plan = solve(table, "kernel-static")
    rows = plan.per_kernel()
    out = {"rows": rows, "totals": plan.summary()}
    if verbose:
        print(f"[kernel_table] {len(rows)} kernels, global strict waste: "
              f"t={plan.summary()['time_pct']}% "
              f"e={plan.summary()['energy_pct']}%")
        hdr = f"{'kernel':28s} {'mem':>7s} {'core':>7s} {'time%':>8s} {'energy%':>9s}"
        print(hdr)
        for r in rows:
            print(f"{r['kernel'][:28]:28s} {str(r['mem']):>7s} "
                  f"{str(r['core']):>7s} {r['time_pct']:+8.2f} "
                  f"{r['energy_pct']:+9.2f}")
    save_artifact("kernel_table", out)
    return out


if __name__ == "__main__":
    main()
