"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = the benchmark's headline
metric, typically the energy saving in percent).
"""
from __future__ import annotations

import sys
import time

from . import (pass_level, kernel_overview, kernel_table, totals,
               relaxed_waste, validation, data_parallel, tensor_parallel,
               heterogeneity, switch_latency, dvfs_by_arch, roofline,
               search_cost)


def _derived(name, out):
    try:
        if name == "pass_level":
            return out["strict_totals"]["energy_pct"]
        if name == "kernel_overview":
            return out["n_kernels"]
        if name == "kernel_table":
            return out["totals"]["energy_pct"]
        if name == "totals":
            return next(r["energy_pct"] for r in out["rows"]
                        if r["plan"] == "kernel-global")
        if name == "relaxed_waste":
            return out["rows"][0]["global_energy_pct"]
        if name == "validation":
            return out["realized_energy_pct_mean"]
        if name == "data_parallel":
            return out["energy_spread_pp"]
        if name == "tensor_parallel":
            return out["energy_spread_pp"]
        if name == "heterogeneity":
            return out["a4000"]["waste"]["energy_pct"]
        if name == "switch_latency":
            return out["rows"][1]["coalesced_energy_pct"]  # 1us IVR point
        if name == "dvfs_by_arch":
            import numpy as np
            return float(np.mean([r["energy_pct"] for r in out["rows"]]))
        if name == "search_cost":
            return out["rows"][1]["cost_frac"]
        if name == "roofline":
            ok = [r for r in out["rows"] if r.get("status") == "ok"]
            return len(ok)
    except Exception:
        return ""
    return ""


BENCHES = [
    ("pass_level", pass_level.main),            # Fig 3-4, §5
    ("kernel_overview", kernel_overview.main),  # Fig 5
    ("kernel_table", kernel_table.main),        # Table 1
    ("totals", totals.main),                    # Table 2
    ("relaxed_waste", relaxed_waste.main),      # Fig 6
    ("validation", validation.main),            # Fig 7 (validation)
    ("data_parallel", data_parallel.main),      # Fig 7 / §7
    ("tensor_parallel", tensor_parallel.main),  # Fig 8 / §8
    ("heterogeneity", heterogeneity.main),      # §9
    ("switch_latency", switch_latency.main),    # §9, beyond-paper
    ("dvfs_by_arch", dvfs_by_arch.main),        # beyond-paper, 10 archs
    ("search_cost", search_cost.main),          # beyond-paper, §4 search
    ("roofline", roofline.main),                # §Roofline
]


def main() -> None:
    rows = []
    for name, fn in BENCHES:
        t0 = time.perf_counter()
        try:
            out = fn(verbose=True)
            err = None
        except Exception as e:  # keep the suite running
            out, err = None, repr(e)
        dt = (time.perf_counter() - t0) * 1e6
        derived = _derived(name, out) if out is not None else f"ERR:{err}"
        rows.append((name, dt, derived))
        print(f"--- {name}: {dt/1e6:.2f}s ---\n", flush=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
