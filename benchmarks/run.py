"""Benchmark driver: one function per paper table/figure.

``python -m benchmarks.run`` runs every registered benchmark and prints
``name,us_per_call,derived`` CSV (derived = the benchmark's headline
metric, typically the energy saving in percent).  ``--list`` prints the
registry (the names ``docs/claims.md`` maps paper claims onto; the
``make docs-check`` gate verifies every documented command against it);
``--only NAME [NAME...]`` runs a subset.
"""
from __future__ import annotations

import argparse
import time

from . import (pass_level, kernel_overview, kernel_table, totals,
               relaxed_waste, validation, data_parallel, tensor_parallel,
               heterogeneity, switch_latency, dvfs_by_arch, roofline,
               search_cost, serve_continuous, serve_fleet,
               serve_prefix, train_dvfs)


def _derived(name, out):
    try:
        if name == "pass_level":
            return out["strict_totals"]["energy_pct"]
        if name == "kernel_overview":
            return out["n_kernels"]
        if name == "kernel_table":
            return out["totals"]["energy_pct"]
        if name == "totals":
            return next(r["energy_pct"] for r in out["rows"]
                        if r["plan"] == "kernel-global")
        if name == "relaxed_waste":
            return out["rows"][0]["global_energy_pct"]
        if name == "validation":
            return out["realized_energy_pct_mean"]
        if name == "data_parallel":
            return out["energy_spread_pp"]
        if name == "tensor_parallel":
            return out["energy_spread_pp"]
        if name == "heterogeneity":
            return out["a4000"]["waste"]["energy_pct"]
        if name == "switch_latency":
            return out["rows"][1]["coalesced_energy_pct"]  # 1us IVR point
        if name == "dvfs_by_arch":
            import numpy as np
            return float(np.mean([r["energy_pct"] for r in out["rows"]]))
        if name == "search_cost":
            return out["rows"][1]["cost_frac"]
        if name == "roofline":
            ok = [r for r in out["rows"] if r.get("status") == "ok"]
            return len(ok)
        if name == "serve_continuous":
            return out["energy"]["totals"]["energy_pct"]
        if name == "serve_fleet":
            return out["router"]["j_per_tok_vs_rr_pct"]
        if name == "serve_prefix":
            return out["replan"]["recovered_frac"]
        if name == "train_dvfs":
            return out["kernel_level"]["energy_pct"]
    except Exception:
        return ""
    return ""


BENCHES = [
    ("pass_level", pass_level.main),            # Fig 3-4, §5
    ("kernel_overview", kernel_overview.main),  # Fig 5
    ("kernel_table", kernel_table.main),        # Table 1
    ("totals", totals.main),                    # Table 2
    ("relaxed_waste", relaxed_waste.main),      # Fig 6
    ("validation", validation.main),            # Fig 7 (validation)
    ("data_parallel", data_parallel.main),      # Fig 7 / §7
    ("tensor_parallel", tensor_parallel.main),  # Fig 8 / §8
    ("heterogeneity", heterogeneity.main),      # §9
    ("switch_latency", switch_latency.main),    # §9, beyond-paper
    ("dvfs_by_arch", dvfs_by_arch.main),        # beyond-paper, 10 archs
    ("search_cost", search_cost.main),          # beyond-paper, §4 search
    ("roofline", roofline.main),                # §Roofline
    ("train_dvfs", train_dvfs.main),            # §5-6 executed + §7-8 xfer
    ("serve_continuous", serve_continuous.main),  # serving stack, §10-11
    ("serve_fleet", serve_fleet.main),          # fleet tier, beyond-paper
    ("serve_prefix", serve_prefix.main),        # prefix cache, claim 15
]

REGISTRY = dict(BENCHES)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark names and exit")
    ap.add_argument("--only", nargs="+", metavar="NAME",
                    help="run only these registered benchmarks")
    args = ap.parse_args(argv)

    if args.list:
        for name, _ in BENCHES:
            print(name)
        return

    selected = BENCHES
    if args.only:
        unknown = [n for n in args.only if n not in REGISTRY]
        if unknown:
            raise SystemExit(f"unknown benchmark(s) {unknown}; "
                             f"--list shows the registry")
        selected = [(n, REGISTRY[n]) for n in args.only]

    rows = []
    for name, fn in selected:
        t0 = time.perf_counter()
        try:
            out = fn(verbose=True)
            err = None
        except Exception as e:  # keep the suite running
            out, err = None, repr(e)
        dt = (time.perf_counter() - t0) * 1e6
        derived = _derived(name, out) if out is not None else f"ERR:{err}"
        rows.append((name, dt, derived))
        print(f"--- {name}: {dt/1e6:.2f}s ---\n", flush=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
