"""Beyond-paper (§9 discussion made quantitative): energy savings vs clock
switch latency, with and without switch-aware coalescing.

The paper observes switching latency 'worsens the DVFS potential' but
cannot act on it.  Our coalescing DP makes the tradeoff explicit: at IVR
latencies (~1 us) the full kernel-level plan survives; at nvidia-smi
latencies (~100 ms) the coalesced plan degrades gracefully toward
pass-level behavior instead of blowing the time budget.
"""
from __future__ import annotations

import numpy as np

from repro.core import (WastePolicy, coalesced_global_plan, global_plan,
                        expand_sequence, schedule_from_coalesced)
from .common import gpt3xl_campaign, save_artifact

LATENCIES = (1e-9, 1e-6, 1e-4, 1e-3, 1e-2, 0.1)


def main(verbose: bool = True):
    camp, table = gpt3xl_campaign()
    seq = expand_sequence(table)
    naive = global_plan(table, WastePolicy(0.0))
    rows = []
    for sl in LATENCIES:
        cp = coalesced_global_plan(table, WastePolicy(0.0),
                                   switch_latency_s=sl, sequence=seq)
        # the naive per-kernel plan executed with real switch costs:
        ch = naive.choice[seq]
        sw = int(np.sum(ch[1:] != ch[:-1]))
        t_naive = float(table.time[seq, ch].sum()) + sw * sl
        e_naive = float(table.energy[seq, ch].sum()) + sw * sl * 100.0
        tb = float(table.time[seq, table.auto_idx].sum())
        eb = float(table.energy[seq, table.auto_idx].sum())
        rows.append({
            "switch_latency_s": sl,
            "coalesced_energy_pct": cp.energy_pct,
            "coalesced_time_pct": cp.time_pct,
            "coalesced_switches": cp.n_switches,
            "naive_energy_pct": 100 * (e_naive / eb - 1),
            "naive_time_pct": 100 * (t_naive / tb - 1),
            "naive_switches": sw,
        })
        if verbose:
            r = rows[-1]
            print(f"[switch_latency] L={sl:8.0e}s  coalesced "
                  f"e={r['coalesced_energy_pct']:+7.2f}% "
                  f"t={r['coalesced_time_pct']:+6.2f}% "
                  f"({r['coalesced_switches']:5d} sw) | naive "
                  f"e={r['naive_energy_pct']:+7.2f}% "
                  f"t={r['naive_time_pct']:+7.2f}% "
                  f"({r['naive_switches']:5d} sw)")
    save_artifact("switch_latency", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
