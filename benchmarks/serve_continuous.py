"""Continuous batching vs wave batching: throughput, tail latency, energy,
paging, quantized KV, planner cost.

Five claims, measured:

1. **Scheduling** — on a skewed generation-length workload (a straggler in
   every wave), the continuous engine keeps every slot busy while the wave
   engine idles short requests behind the wave straggler.  Measured as
   real wall-clock tokens/sec and per-request completion "latency" (decode
   steps until a request finishes) on a CPU smoke model.  The engine's
   decode hot path is *sync-free*: batched bucketed prefill, on-device
   EOS/max-len termination, multi-chunk rounds with one host round-trip.
2. **Paging** — the same workload served by the paged-KV engine with
   **2x the slots at the same attention-KV HBM budget** (block-table page
   pool sized to the dense engine's byte count).
3. **Quantized KV** — an int8 (``--kv-dtype``) page pool doubles the page
   count of the bf16-paged pool and serves **2x the paged slot count at
   no more attention-KV HBM**, quantize-on-write + fused in-kernel
   dequant; measured peak pool occupancy backs the capacity claim.
4. **DVFS** — a :class:`~repro.dvfs.DvfsSession` plans every serving
   phase (prefill + per-bucket decode, for the full-size arch on the
   TPU-v5e-like chip) and the engine replays the resulting
   :class:`~repro.dvfs.DvfsPlan` through the session's governor
   executor, reporting executed energy vs the auto governor at <= the
   policy's time budget, with per-phase switch counts.  A second plan
   pass re-plans the decode phases on the *quantized* workload model
   (halved cache-read stream): the roofline feedback loop, recorded as
   per-bucket planned energy vs the bf16 plan at the same tau.
5. **Planner cost** — wall time of the (vectorized) phase-bundle planning
   itself, the number future PRs diff against.

Besides the usual artifact, the run writes a repo-root ``BENCH_serve.json``
(tokens/sec for the continuous *and* quantized engines, energy delta,
quantized-plan feedback record, planner wall time) as the perf trajectory
anchor; ``make bench-smoke`` re-runs the throughput section at toy scale
and fails on a >10% regression against that file, naming the offending
anchor and its delta.

Run:  PYTHONPATH=src python -m benchmarks.serve_continuous [--kv-dtype int8]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

ARCH = "llama3.2-1b"
SLOTS = 4
MAX_SEQ = 96
PAGE = 16
TAU = 0.005
N_REQUESTS = 16
KV_DTYPE = "int8"        # default --kv-dtype axis value
# decode shape for the roofline-feedback plan comparison: long contexts,
# the regime the doubled pool capacity exists to serve
FEEDBACK_DECODE_SEQ = 4096

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")


def _requests(vocab: int, n: int = N_REQUESTS):
    """Skewed mix: mostly short generations, a 6x straggler every 4th
    request (the wave scheduler's worst case)."""
    import jax  # noqa: F401  (repro.serve pulls jax; keep import local)
    from repro.serve import Request
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        plen = 8 if i % 2 == 0 else 12
        new = 48 if i % 4 == 1 else int(rng.integers(4, 10))
        reqs.append(Request(uid=i,
                            prompt=rng.integers(0, vocab, plen),
                            max_new_tokens=new))
    return reqs


def _drive(eng, vocab, n: int = N_REQUESTS, passes: int = 3) -> Dict:
    """Warm-up pass (compiles), then the best of ``passes`` timed
    steady-state passes (host scheduling noise dominates at this scale;
    steady-state throughput is the quantity under test)."""
    eng.generate(_requests(vocab, n))                 # warm-up
    best = None
    for _ in range(passes):
        eng.reset()
        reqs = _requests(vocab, n)
        t0 = time.perf_counter()
        eng.generate(reqs)
        dt = time.perf_counter() - t0
        if best is None or dt < best[0]:
            best = (dt, reqs, eng.n_decode_steps)
    dt, reqs, decode_steps = best
    tokens = sum(len(r.generated) for r in reqs)
    lat = np.array([r.finished_step for r in reqs], dtype=float)
    return {"wall_s": dt, "tokens": tokens,
            "tokens_per_s": tokens / dt,
            "decode_steps": decode_steps,
            "latency_steps_p50": float(np.percentile(lat, 50)),
            "latency_steps_p95": float(np.percentile(lat, 95))}


def _write_bench_file(payload: Dict) -> None:
    # merge-write (the serve_prefix benchmark idiom): other benchmarks
    # park their own anchors (prefix_*) in the same file, and a refresh
    # of this benchmark's anchors must not silently drop theirs
    merged: Dict = {}
    if os.path.exists(BENCH_FILE):
        with open(BENCH_FILE) as f:
            merged = json.load(f)
    merged.update(payload)
    with open(BENCH_FILE, "w") as f:
        json.dump(merged, f, indent=1, default=float)
        f.write("\n")


def _raw_chunk_rate(eng, calls: int = 8, windows: int = 2) -> float:
    """Raw jitted chunk-step throughput (steps/sec) on the engine's own
    state: the machine-speed calibration for the regression gate.  The
    engine's *efficiency* (tokens/sec divided by this) is noise-immune —
    host slowdowns hit both numerator and denominator.  Works for dense
    and paged engines (a paged chunk call takes the device block tables
    as an extra, non-donated operand)."""
    import jax
    st = eng.state
    fn = eng._chunk_fn(16)
    if eng.paged:
        st.sync_tables()

    def burst():
        nonlocal st
        args = (eng.params, st.cache, st.tokens, st.pos, st.remaining,
                eng.rng)
        out = fn(*args, st.tables_dev) if eng.paged else fn(*args)
        st.tokens, st.pos, st.cache, st.remaining, eng.rng = out[:5]
        return out[5]

    jax.block_until_ready(burst())                # warm
    best = 0.0
    for _ in range(windows):
        t0 = time.perf_counter()
        for _ in range(calls):
            last = burst()
        jax.block_until_ready(last)
        best = max(best, 16 * calls / (time.perf_counter() - t0))
    return best


_MODEL_CACHE: Dict = {}


def _smoke_model():
    """Build the benchmark's smoke model once per process."""
    if "m" not in _MODEL_CACHE:
        import jax
        from repro.configs import REGISTRY, smoke_config
        from repro.models import build_model
        cfg = dataclasses.replace(smoke_config(REGISTRY[ARCH]),
                                  compute_dtype="float32")
        model = build_model(cfg, block_k=16)
        params = model.init(jax.random.PRNGKey(0))
        _MODEL_CACHE["m"] = (model, params, cfg)
    return _MODEL_CACHE["m"]


def throughput_section(n_requests: int = N_REQUESTS,
                       include_wave: bool = True, passes: int = 3,
                       kv_dtype: str = KV_DTYPE) -> Dict:
    """Wave vs continuous vs paged-2x vs quantized-4x throughput on the
    skewed workload."""
    from repro.serve import ServeEngine, WaveEngine

    model, params, cfg = _smoke_model()

    out: Dict = {"arch": ARCH, "slots": SLOTS, "n_requests": n_requests}
    if include_wave:
        out["wave"] = _drive(WaveEngine(model, params, batch_slots=SLOTS,
                                        max_seq=MAX_SEQ), cfg.vocab_size,
                             n_requests)
    cont = ServeEngine(model, params, batch_slots=SLOTS, max_seq=MAX_SEQ)
    out["continuous"] = _drive(cont, cfg.vocab_size, n_requests,
                               passes=passes)
    # dense engines: kv_hbm_bytes is the attention-KV subset of the cache
    # (what paging would pool); cache_hbm_bytes adds dense SSM/conv state
    out["continuous"]["kv_hbm_bytes"] = cont.state.kv_hbm_bytes()
    out["continuous"]["cache_hbm_bytes"] = cont.state.cache_hbm_bytes()
    out["compile_stats"] = cont.compile_stats
    out["raw_chunk_steps_per_s"] = _raw_chunk_rate(cont)
    out["engine_efficiency"] = (out["continuous"]["tokens_per_s"]
                                / out["raw_chunk_steps_per_s"])
    if include_wave:
        out["throughput_speedup"] = (out["continuous"]["tokens_per_s"]
                                     / out["wave"]["tokens_per_s"])

    # paged engine: 2x the slots, page pool capped at the dense engine's
    # token capacity (SLOTS * MAX_SEQ) -> same attention-KV HBM budget
    paged = ServeEngine(model, params, batch_slots=2 * SLOTS,
                        max_seq=MAX_SEQ, paged=True, page_size=PAGE,
                        n_pages=SLOTS * MAX_SEQ // PAGE)
    out["paged_2x_slots"] = _drive(paged, cfg.vocab_size, n_requests)
    out["paged_2x_slots"]["kv_hbm_bytes"] = paged.state.kv_hbm_bytes()
    out["paged_2x_slots"]["slots"] = 2 * SLOTS
    out["paged_2x_slots"]["pool"] = paged.state.pool.stats()

    # quantized page pool: double the page count of the bf16-paged pool
    # (byte-identical at the bf16 serving dtype; the float32 smoke store
    # makes it ~0.5x here) and serve 2x the paged slot count again
    quant = ServeEngine(model, params, batch_slots=4 * SLOTS,
                        max_seq=MAX_SEQ, paged=True, page_size=PAGE,
                        n_pages=2 * SLOTS * MAX_SEQ // PAGE,
                        kv_dtype=kv_dtype)
    q = _drive(quant, cfg.vocab_size, n_requests, passes=passes)
    q["kv_dtype"] = kv_dtype
    q["slots"] = 4 * SLOTS
    q["kv_hbm_bytes"] = quant.state.kv_hbm_bytes()
    q["pool"] = quant.state.pool.stats()
    q["slot_ratio_vs_paged"] = (4 * SLOTS) / (2 * SLOTS)
    q["kv_hbm_ratio_vs_paged"] = (q["kv_hbm_bytes"]
                                  / out["paged_2x_slots"]["kv_hbm_bytes"])
    q["raw_chunk_steps_per_s"] = _raw_chunk_rate(quant)
    q["engine_efficiency"] = q["tokens_per_s"] / q["raw_chunk_steps_per_s"]
    out["quantized"] = q
    return out


def trace_overhead_section(passes: int = 12) -> Dict:
    """Tokens/sec with the obs tracer attached vs without, same smoke
    engine; the ratio gates tracing's hot-path cost (<= 1% target).
    The timed passes interleave the two engines in *alternating* order
    and each side keeps its best — host scheduling noise at this scale
    swings single runs +/-15%, far above the real cost of a few dict
    appends (~10us/event, <1% of a run), and alternating best-of-N
    pits both sides against the same noise floor."""
    from repro.obs import Tracer
    from repro.serve import ServeEngine

    model, params, cfg = _smoke_model()
    plain = ServeEngine(model, params, batch_slots=SLOTS, max_seq=MAX_SEQ)
    tracer = Tracer(meta={"bench": "trace_overhead"})
    traced = ServeEngine(model, params, batch_slots=SLOTS, max_seq=MAX_SEQ,
                         tracer=tracer)
    plain.generate(_requests(cfg.vocab_size))          # warm-up/compile
    traced.generate(_requests(cfg.vocab_size))
    times: Dict[str, List[float]] = {"plain": [], "traced": []}
    tokens = 0
    for i in range(passes):
        order = (("plain", plain), ("traced", traced))
        for name, eng in (order if i % 2 == 0 else order[::-1]):
            eng.reset()
            reqs = _requests(cfg.vocab_size)
            t0 = time.perf_counter()
            eng.generate(reqs)
            times[name].append(time.perf_counter() - t0)
            tokens = sum(len(r.generated) for r in reqs)
    p = tokens / min(times["plain"])
    t = tokens / min(times["traced"])
    return {"plain_tokens_per_s": p, "traced_tokens_per_s": t,
            "traced_events": len(tracer.events),
            "trace_overhead": t / p}


def planner_feedback_section(kv_dtype: str = KV_DTYPE,
                             n_reps: int = 10) -> Dict:
    """Re-plan the decode phases on the quantized workload model and
    compare against the bf16 plan at the same tau.

    KV quantization halves the decode cache-read stream, so the planner
    sees a higher-arithmetic-intensity decode roofline: planned base
    time/energy drop, the coalesced clock schedule re-groups, and the
    governed (planned) decode energy lands strictly below the bf16 plan's
    at every bucket — a strictly deeper serve energy cut at the same tau
    when both are measured against the shared un-governed bf16 baseline.
    """
    from repro.configs import REGISTRY
    from repro.configs.base import ShapeConfig
    from repro.core.objectives import WastePolicy
    from repro.core.phase_plan import plan_phase_bundle
    from repro.core.power_model import get_chip

    full = REGISTRY[ARCH]
    chip = get_chip("tpu-v5e")
    pre = ShapeConfig(name="serve_prefill", seq_len=512, global_batch=1,
                      kind="prefill")
    dec = ShapeConfig(name="serve_decode_kv", seq_len=FEEDBACK_DECODE_SEQ,
                      global_batch=2 * SLOTS, kind="decode")
    metas: Dict[str, Dict] = {}
    for kvd in (None, kv_dtype):
        bundle = plan_phase_bundle(
            full, chip, n_slots=2 * SLOTS, prefill_shape=pre,
            decode_shape=dec, policy=WastePolicy(TAU), n_reps=n_reps,
            kv_dtype=kvd)
        metas[kvd or "bf16"] = {
            ph: p.schedule.meta for ph, p in bundle.phases().items()
            if ph.startswith("decode@")}

    buckets: Dict[str, Dict] = {}
    for ph in sorted(metas["bf16"], key=lambda s: int(s.split("@")[1])):
        m0, m1 = metas["bf16"][ph], metas[kv_dtype][ph]
        g0 = m0["base_energy_j"] * (1 + m0["energy_pct"] / 100)
        g1 = m1["base_energy_j"] * (1 + m1["energy_pct"] / 100)
        buckets[ph] = {
            "bf16_energy_pct": m0["energy_pct"],
            "quant_energy_pct": m1["energy_pct"],
            "bf16_energy_gov_j": g0, "quant_energy_gov_j": g1,
            # serve energy cut at the same tau, both against the shared
            # un-governed bf16 baseline (quantization + DVFS compound)
            "bf16_cut_vs_base": 1 - g0 / m0["base_energy_j"],
            "quant_cut_vs_base": 1 - g1 / m0["base_energy_j"],
        }
    top = max(buckets, key=lambda s: int(s.split("@")[1]))
    return {"kv_dtype": kv_dtype, "tau": TAU,
            "decode_seq_len": FEEDBACK_DECODE_SEQ, "n_slots": 2 * SLOTS,
            "buckets": buckets, "top_bucket": top,
            **{f"top_{k}": v for k, v in buckets[top].items()}}


def main(verbose: bool = True, kv_dtype: str = KV_DTYPE) -> Dict:
    from repro.configs import REGISTRY
    from repro.configs.base import ShapeConfig
    from repro.dvfs import DvfsSession
    from repro.serve import ServeEngine
    from .common import save_artifact

    # --- 1-3. scheduling + paging + quantized: wall-clock tokens/sec ----
    out = throughput_section(kv_dtype=kv_dtype)
    speedup = out["throughput_speedup"]

    # --- 4. DVFS: plan the full-size arch, replay through the engine ----
    # One DvfsSession runs campaign -> plan -> govern -> meter; the
    # kernel-static governor + simulated controller reproduce the legacy
    # plan_phase_bundle/PhaseExecutor pipeline bit-for-bit.
    full = REGISTRY[ARCH]
    pre = ShapeConfig(name="serve_prefill", seq_len=512, global_batch=1,
                      kind="prefill")
    dec = ShapeConfig(name="serve_decode", seq_len=512, global_batch=SLOTS,
                      kind="decode")
    from repro.obs import Tracer
    tracer = Tracer(meta={"bench": "serve_continuous", "arch": ARCH,
                          "chip": "tpu-v5e", "tau": TAU})
    sess = DvfsSession(chip="tpu-v5e", tau=TAU, n_reps=10, tracer=tracer)
    sess.plan_serve(full, n_slots=SLOTS, prefill_shape=pre,
                    decode_shape=dec)
    planner_wall_s = sess.planner_wall_s
    chip = sess.chip
    model, params, cfg = _smoke_model()
    eng = ServeEngine(model, params, batch_slots=SLOTS, max_seq=MAX_SEQ,
                      executor=sess.serve_executor(), tracer=tracer)
    eng.generate(_requests(cfg.vocab_size))
    energy = eng.energy_summary()
    sess.close()
    os.makedirs("artifacts", exist_ok=True)
    trace_path = tracer.save("artifacts/serve_continuous.trace.json")

    # --- 4a. tracing overhead on the hot path (gated in bench-smoke) ----
    overhead = trace_overhead_section()

    # --- 4b. roofline feedback: re-plan on the quantized workload -------
    feedback = planner_feedback_section(kv_dtype=kv_dtype)

    out.update({"tau": TAU, "energy": energy,
                "planner_wall_s": planner_wall_s,
                "quantized_plan": feedback,
                "trace_overhead": overhead,
                "trace_path": trace_path})
    save_artifact("serve_continuous", out)

    # --- 5. perf-trajectory anchor (repo root, diffed by future PRs) ----
    tot = energy["totals"]
    q = out["quantized"]
    _write_bench_file({
        "arch": ARCH, "slots": SLOTS, "n_requests": N_REQUESTS,
        "tokens_per_s": out["continuous"]["tokens_per_s"],
        "engine_efficiency": out["engine_efficiency"],
        "paged_2x_tokens_per_s": out["paged_2x_slots"]["tokens_per_s"],
        "throughput_speedup_vs_wave": speedup,
        "kv_dtype": kv_dtype,
        "quantized_tokens_per_s": q["tokens_per_s"],
        "quantized_engine_efficiency": q["engine_efficiency"],
        "quantized_slots": q["slots"],
        "quantized_slot_ratio_vs_paged": q["slot_ratio_vs_paged"],
        "quantized_kv_hbm_ratio_vs_paged": q["kv_hbm_ratio_vs_paged"],
        "quantized_peak_allocated_pages":
            q["pool"]["peak_allocated_pages"],
        "quantized_plan": feedback,
        "energy_pct": tot["energy_pct"], "time_pct": tot["time_pct"],
        "tau": TAU, "planner_wall_s": planner_wall_s,
        "trace_overhead": overhead["trace_overhead"],
    })

    if verbose:
        print(f"skewed workload, {N_REQUESTS} requests, {SLOTS} slots:")
        for tag in ("wave", "continuous", "paged_2x_slots", "quantized"):
            r = out[tag]
            print(f"  {tag:14s}: {r['tokens']} tok in {r['wall_s']:.2f}s"
                  f" ({r['tokens_per_s']:.1f} tok/s,"
                  f" {r['decode_steps']} decode steps,"
                  f" p50/p95 latency {r['latency_steps_p50']:.0f}/"
                  f"{r['latency_steps_p95']:.0f} steps)")
        print(f"  speedup    : {speedup:.2f}x tokens/sec (continuous/wave)")
        pp = out["paged_2x_slots"]["pool"]
        print(f"  paged      : {out['paged_2x_slots']['slots']} slots at "
              f"{out['paged_2x_slots']['kv_hbm_bytes']/1e3:.0f} kB paged "
              f"attention-KV vs dense "
              f"{out['continuous']['kv_hbm_bytes']/1e3:.0f} kB "
              f"attention-KV for {SLOTS} "
              f"(+{(out['continuous']['cache_hbm_bytes'] - out['continuous']['kv_hbm_bytes'])/1e3:.0f} kB "
              f"non-KV state); peak {pp['peak_allocated_pages']}"
              f"/{pp['n_pages']} pages")
        qp = q["pool"]
        print(f"  quantized  : {q['slots']} slots ({q['kv_dtype']}) at "
              f"{q['kv_hbm_bytes']/1e3:.0f} kB "
              f"({q['kv_hbm_ratio_vs_paged']:.2f}x paged bytes, "
              f"{q['slot_ratio_vs_paged']:.1f}x slots); peak "
              f"{qp['peak_allocated_pages']}/{qp['n_pages']} pages")
        print(f"  compile    : {out['compile_stats']}")
        print(f"  planner    : {planner_wall_s:.2f}s wall "
              f"(vectorized phase-bundle planning)")
        print(f"  tracing    : {overhead['trace_overhead']:.3f}x tokens/s "
              f"with tracer attached ({overhead['traced_events']} events); "
              f"trace -> {trace_path}")
        print(f"DVFS replay ({full.name} on {chip.name}, tau={TAU}):")
        for name, row in energy["phases"].items():
            if row["steps"]:
                print(f"  {name:10s} steps={row['steps']:3d} "
                      f"switches={row['n_switches']:3d} "
                      f"time {row['time_pct']:+7.4f}%  "
                      f"energy {row['energy_pct']:+8.3f}%")
        print(f"  total      time {tot['time_pct']:+7.4f}% "
              f"(budget {100*TAU:+.2f}%)  energy {tot['energy_pct']:+8.3f}%"
              f"  switches={tot['n_switches']}")
        print(f"quantized re-plan ({full.name}, decode "
              f"S={FEEDBACK_DECODE_SEQ}, {2*SLOTS} slots, same tau):")
        for ph, row in feedback["buckets"].items():
            print(f"  {ph:10s} planned energy "
                  f"{row['bf16_energy_gov_j']:.4f} J -> "
                  f"{row['quant_energy_gov_j']:.4f} J; cut vs bf16 base "
                  f"{100*row['bf16_cut_vs_base']:.2f}% -> "
                  f"{100*row['quant_cut_vs_base']:.2f}%")
    return out


def smoke(check: bool = True, tolerance: float = 0.10,
          confirm_retries: int = 2) -> int:
    """Toy-scale throughput run; non-zero exit on >tolerance regression
    against the checked-in ``BENCH_serve.json`` (``make bench-smoke``).

    Gates the continuous *and* the quantized engine.  Each variant passes
    if EITHER its absolute tokens/sec clears the floor OR its *normalized*
    engine efficiency does (tokens/sec over the same process's raw jitted
    chunk-step rate — a 2-core CI box swings its absolute wall clock
    +/-20% between processes, which the normalization cancels; a real
    hot-path regression lowers both measures).  A miss is re-confirmed
    with fresh best-of-5 attempts before failing; the failure output
    names the offending anchor(s) and prints the delta vs baseline."""
    kv_dtype = KV_DTYPE
    if os.path.exists(BENCH_FILE):
        with open(BENCH_FILE) as f:
            base = json.load(f)
        kv_dtype = base.get("kv_dtype", KV_DTYPE)
    out = throughput_section(include_wave=False, passes=5,
                             kv_dtype=kv_dtype)
    # variant -> {anchor_name: measured}; gate is per-variant OR over its
    # two anchors (absolute + normalized)
    vals: Dict[str, Dict[str, float]] = {
        "continuous": {
            "tokens_per_s": out["continuous"]["tokens_per_s"],
            "engine_efficiency": out["engine_efficiency"]},
        "quantized": {
            "quantized_tokens_per_s": out["quantized"]["tokens_per_s"],
            "quantized_engine_efficiency":
                out["quantized"]["engine_efficiency"]},
    }
    print(f"bench-smoke: continuous "
          f"{vals['continuous']['tokens_per_s']:.1f} tok/s "
          f"(efficiency {vals['continuous']['engine_efficiency']:.3f}), "
          f"quantized[{kv_dtype}] "
          f"{vals['quantized']['quantized_tokens_per_s']:.1f} tok/s "
          f"(efficiency "
          f"{vals['quantized']['quantized_engine_efficiency']:.3f})")
    if not check:
        return 0
    if not os.path.exists(BENCH_FILE):
        print(f"bench-smoke: no {os.path.basename(BENCH_FILE)} baseline; "
              f"run `python -m benchmarks.serve_continuous` first")
        return 1
    if "tokens_per_s" not in base or "engine_efficiency" not in base:
        print("bench-smoke: baseline lacks tokens_per_s/engine_efficiency;"
              " refresh it with `python -m benchmarks.serve_continuous`")
        return 1
    gated = ["continuous"]
    if "quantized_tokens_per_s" in base:
        gated.append("quantized")
    else:
        print("bench-smoke: baseline predates the quantized anchors; "
              "gating continuous only (refresh BENCH_serve.json to gate "
              "the quantized variant)")

    def failing(variant: str) -> List[Tuple[str, float, float]]:
        """Anchors of ``variant`` below floor; empty when it passes."""
        misses = [(name, val, base[name] * (1.0 - tolerance))
                  for name, val in vals[variant].items()
                  if val < base[name] * (1.0 - tolerance)]
        # OR-gate: one clearing anchor clears the variant
        return misses if len(misses) == len(vals[variant]) else []

    for attempt in range(confirm_retries):
        bad = [v for v in gated if failing(v)]
        if not bad:
            break
        print(f"bench-smoke: {', '.join(bad)} below floor; re-confirming "
              f"({attempt + 1}/{confirm_retries})")
        retry = throughput_section(include_wave=False, passes=5,
                                   kv_dtype=kv_dtype)
        rvals = {
            "continuous": {
                "tokens_per_s": retry["continuous"]["tokens_per_s"],
                "engine_efficiency": retry["engine_efficiency"]},
            "quantized": {
                "quantized_tokens_per_s":
                    retry["quantized"]["tokens_per_s"],
                "quantized_engine_efficiency":
                    retry["quantized"]["engine_efficiency"]},
        }
        for variant, row in rvals.items():
            for name, val in row.items():
                vals[variant][name] = max(vals[variant][name], val)

    ok = True
    for variant in gated:
        misses = failing(variant)
        if misses:
            ok = False
            for name, val, floor in misses:
                print(f"bench-smoke FAIL [{name}]: {val:.3f} < floor "
                      f"{floor:.3f} (baseline {base[name]:.3f}, "
                      f"{100 * (val / base[name] - 1):+.1f}%)")
        else:
            anchors = ", ".join(
                f"{name} {val:.3f} (floor {base[name] * (1 - tolerance):.3f})"
                for name, val in vals[variant].items())
            print(f"bench-smoke OK [{variant}]: {anchors}")

    # tracing overhead gate: the obs tracer must cost <= 1% tokens/sec
    # on the hot path (retry-confirm with extra attempts — the ratio is
    # a quotient of two noisy timings, and a genuine >1% cost keeps
    # missing while a noise dip clears on re-measurement)
    if "trace_overhead" in base:
        ratio = trace_overhead_section()["trace_overhead"]
        for attempt in range(confirm_retries + 2):
            if ratio >= 0.99:
                break
            print(f"bench-smoke: trace_overhead {ratio:.3f} below 0.99; "
                  f"re-confirming ({attempt + 1}/{confirm_retries + 2})")
            ratio = max(ratio,
                        trace_overhead_section()["trace_overhead"])
        if ratio < 0.99:
            ok = False
            print(f"bench-smoke FAIL [trace_overhead]: {ratio:.3f} < "
                  f"0.99 (tracing costs >1% tokens/sec)")
        else:
            print(f"bench-smoke OK [trace_overhead]: {ratio:.3f} "
                  f"(floor 0.990)")
    print(f"bench-smoke: {tolerance:.0%} tolerance -> "
          f"{'OK' if ok else 'REGRESSION'}")
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser(prog="benchmarks.serve_continuous")
    ap.add_argument("--smoke", action="store_true",
                    help="throughput-only toy run (skips DVFS planning)")
    ap.add_argument("--check", action="store_true",
                    help="with --smoke: fail on >10%% regression vs "
                         "BENCH_serve.json (names the offending anchor)")
    ap.add_argument("--kv-dtype", default=KV_DTYPE,
                    help="quantized page-pool dtype for the quantized "
                         "axis (default: %(default)s)")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(check=args.check))
    main(kv_dtype=args.kv_dtype)
