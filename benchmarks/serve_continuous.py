"""Continuous batching vs wave batching: throughput, tail latency, energy,
paging, planner cost.

Four claims, measured:

1. **Scheduling** — on a skewed generation-length workload (a straggler in
   every wave), the continuous engine keeps every slot busy while the wave
   engine idles short requests behind the wave straggler.  Measured as
   real wall-clock tokens/sec and per-request completion "latency" (decode
   steps until a request finishes) on a CPU smoke model.  The engine's
   decode hot path is *sync-free*: batched bucketed prefill, on-device
   EOS/max-len termination, multi-chunk rounds with one host round-trip.
2. **Paging** — the same workload served by the paged-KV engine with
   **2x the slots at the same KV HBM budget** (block-table page pool
   sized to the dense engine's byte count).
3. **DVFS** — a :class:`~repro.dvfs.DvfsSession` plans every serving
   phase (prefill + per-bucket decode, for the full-size arch on the
   TPU-v5e-like chip) and the engine replays the resulting
   :class:`~repro.dvfs.DvfsPlan` through the session's governor
   executor, reporting executed energy vs the auto governor at <= the
   policy's time budget, with per-phase switch counts.
4. **Planner cost** — wall time of the (vectorized) phase-bundle planning
   itself, the number future PRs diff against.

Besides the usual artifact, the run writes a repo-root ``BENCH_serve.json``
(tokens/sec, energy delta, planner wall time) as the perf trajectory
anchor; ``make bench-smoke`` re-runs the throughput section at toy scale
and fails on a >10% tokens/sec regression against that file.

Run:  PYTHONPATH=src python -m benchmarks.serve_continuous
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict

import numpy as np

ARCH = "llama3.2-1b"
SLOTS = 4
MAX_SEQ = 96
PAGE = 16
TAU = 0.005
N_REQUESTS = 16

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")


def _requests(vocab: int, n: int = N_REQUESTS):
    """Skewed mix: mostly short generations, a 6x straggler every 4th
    request (the wave scheduler's worst case)."""
    import jax  # noqa: F401  (repro.serve pulls jax; keep import local)
    from repro.serve import Request
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        plen = 8 if i % 2 == 0 else 12
        new = 48 if i % 4 == 1 else int(rng.integers(4, 10))
        reqs.append(Request(uid=i,
                            prompt=rng.integers(0, vocab, plen),
                            max_new_tokens=new))
    return reqs


def _drive(eng, vocab, n: int = N_REQUESTS, passes: int = 3) -> Dict:
    """Warm-up pass (compiles), then the best of ``passes`` timed
    steady-state passes (host scheduling noise dominates at this scale;
    steady-state throughput is the quantity under test)."""
    eng.generate(_requests(vocab, n))                 # warm-up
    best = None
    for _ in range(passes):
        eng.reset()
        reqs = _requests(vocab, n)
        t0 = time.perf_counter()
        eng.generate(reqs)
        dt = time.perf_counter() - t0
        if best is None or dt < best[0]:
            best = (dt, reqs, eng.n_decode_steps)
    dt, reqs, decode_steps = best
    tokens = sum(len(r.generated) for r in reqs)
    lat = np.array([r.finished_step for r in reqs], dtype=float)
    return {"wall_s": dt, "tokens": tokens,
            "tokens_per_s": tokens / dt,
            "decode_steps": decode_steps,
            "latency_steps_p50": float(np.percentile(lat, 50)),
            "latency_steps_p95": float(np.percentile(lat, 95))}


def _write_bench_file(payload: Dict) -> None:
    with open(BENCH_FILE, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")


def _raw_chunk_rate(eng, calls: int = 8, windows: int = 2) -> float:
    """Raw jitted chunk-step throughput (steps/sec) on the engine's own
    state: the machine-speed calibration for the regression gate.  The
    engine's *efficiency* (tokens/sec divided by this) is noise-immune —
    host slowdowns hit both numerator and denominator."""
    import jax
    st = eng.state
    fn = eng._chunk_fn(16)

    def burst():
        nonlocal st
        out = fn(eng.params, st.cache, st.tokens, st.pos, st.remaining,
                 eng.rng)
        st.tokens, st.pos, st.cache, st.remaining, eng.rng = out[:5]
        return out[5]

    jax.block_until_ready(burst())                # warm
    best = 0.0
    for _ in range(windows):
        t0 = time.perf_counter()
        for _ in range(calls):
            last = burst()
        jax.block_until_ready(last)
        best = max(best, 16 * calls / (time.perf_counter() - t0))
    return best


_MODEL_CACHE: Dict = {}


def _smoke_model():
    """Build the benchmark's smoke model once per process."""
    if "m" not in _MODEL_CACHE:
        import jax
        from repro.configs import REGISTRY, smoke_config
        from repro.models import build_model
        cfg = dataclasses.replace(smoke_config(REGISTRY[ARCH]),
                                  compute_dtype="float32")
        model = build_model(cfg, block_k=16)
        params = model.init(jax.random.PRNGKey(0))
        _MODEL_CACHE["m"] = (model, params, cfg)
    return _MODEL_CACHE["m"]


def throughput_section(n_requests: int = N_REQUESTS,
                       include_wave: bool = True, passes: int = 3) -> Dict:
    """Wave vs continuous vs paged-2x throughput on the skewed workload."""
    from repro.serve import ServeEngine, WaveEngine

    model, params, cfg = _smoke_model()

    out: Dict = {"arch": ARCH, "slots": SLOTS, "n_requests": n_requests}
    if include_wave:
        out["wave"] = _drive(WaveEngine(model, params, batch_slots=SLOTS,
                                        max_seq=MAX_SEQ), cfg.vocab_size,
                             n_requests)
    cont = ServeEngine(model, params, batch_slots=SLOTS, max_seq=MAX_SEQ)
    out["continuous"] = _drive(cont, cfg.vocab_size, n_requests,
                               passes=passes)
    out["continuous"]["kv_hbm_bytes"] = cont.state.kv_hbm_bytes()
    out["compile_stats"] = cont.compile_stats
    out["raw_chunk_steps_per_s"] = _raw_chunk_rate(cont)
    out["engine_efficiency"] = (out["continuous"]["tokens_per_s"]
                                / out["raw_chunk_steps_per_s"])
    if include_wave:
        out["throughput_speedup"] = (out["continuous"]["tokens_per_s"]
                                     / out["wave"]["tokens_per_s"])

    # paged engine: 2x the slots, page pool capped at the dense engine's
    # token capacity (SLOTS * MAX_SEQ) -> same attention-KV HBM budget
    paged = ServeEngine(model, params, batch_slots=2 * SLOTS,
                        max_seq=MAX_SEQ, paged=True, page_size=PAGE,
                        n_pages=SLOTS * MAX_SEQ // PAGE)
    out["paged_2x_slots"] = _drive(paged, cfg.vocab_size, n_requests)
    out["paged_2x_slots"]["kv_hbm_bytes"] = paged.state.kv_hbm_bytes()
    out["paged_2x_slots"]["slots"] = 2 * SLOTS
    out["paged_2x_slots"]["pool"] = paged.state.pool.stats()
    return out


def main(verbose: bool = True) -> Dict:
    from repro.configs import REGISTRY
    from repro.configs.base import ShapeConfig
    from repro.dvfs import DvfsSession
    from repro.serve import ServeEngine
    from .common import save_artifact

    # --- 1-2. scheduling + paging: wall-clock tokens/sec ----------------
    out = throughput_section()
    speedup = out["throughput_speedup"]

    # --- 3. DVFS: plan the full-size arch, replay through the engine ----
    # One DvfsSession runs campaign -> plan -> govern -> meter; the
    # kernel-static governor + simulated controller reproduce the legacy
    # plan_phase_bundle/PhaseExecutor pipeline bit-for-bit.
    full = REGISTRY[ARCH]
    pre = ShapeConfig(name="serve_prefill", seq_len=512, global_batch=1,
                      kind="prefill")
    dec = ShapeConfig(name="serve_decode", seq_len=512, global_batch=SLOTS,
                      kind="decode")
    sess = DvfsSession(chip="tpu-v5e", tau=TAU, n_reps=10)
    sess.plan_serve(full, n_slots=SLOTS, prefill_shape=pre,
                    decode_shape=dec)
    planner_wall_s = sess.planner_wall_s
    chip = sess.chip
    model, params, cfg = _smoke_model()
    eng = ServeEngine(model, params, batch_slots=SLOTS, max_seq=MAX_SEQ,
                      executor=sess.serve_executor())
    eng.generate(_requests(cfg.vocab_size))
    energy = eng.energy_summary()
    sess.close()

    out.update({"tau": TAU, "energy": energy,
                "planner_wall_s": planner_wall_s})
    save_artifact("serve_continuous", out)

    # --- 4. perf-trajectory anchor (repo root, diffed by future PRs) ----
    tot = energy["totals"]
    _write_bench_file({
        "arch": ARCH, "slots": SLOTS, "n_requests": N_REQUESTS,
        "tokens_per_s": out["continuous"]["tokens_per_s"],
        "engine_efficiency": out["engine_efficiency"],
        "paged_2x_tokens_per_s": out["paged_2x_slots"]["tokens_per_s"],
        "throughput_speedup_vs_wave": speedup,
        "energy_pct": tot["energy_pct"], "time_pct": tot["time_pct"],
        "tau": TAU, "planner_wall_s": planner_wall_s,
    })

    if verbose:
        print(f"skewed workload, {N_REQUESTS} requests, {SLOTS} slots:")
        for tag in ("wave", "continuous", "paged_2x_slots"):
            r = out[tag]
            print(f"  {tag:14s}: {r['tokens']} tok in {r['wall_s']:.2f}s"
                  f" ({r['tokens_per_s']:.1f} tok/s,"
                  f" {r['decode_steps']} decode steps,"
                  f" p50/p95 latency {r['latency_steps_p50']:.0f}/"
                  f"{r['latency_steps_p95']:.0f} steps)")
        print(f"  speedup    : {speedup:.2f}x tokens/sec (continuous/wave)")
        print(f"  paged      : {out['paged_2x_slots']['slots']} slots at "
              f"{out['paged_2x_slots']['kv_hbm_bytes']/1e3:.0f} kB KV vs "
              f"dense {out['continuous']['kv_hbm_bytes']/1e3:.0f} kB for "
              f"{SLOTS}")
        print(f"  compile    : {out['compile_stats']}")
        print(f"  planner    : {planner_wall_s:.2f}s wall "
              f"(vectorized phase-bundle planning)")
        print(f"DVFS replay ({full.name} on {chip.name}, tau={TAU}):")
        for name, row in energy["phases"].items():
            if row["steps"]:
                print(f"  {name:10s} steps={row['steps']:3d} "
                      f"switches={row['n_switches']:3d} "
                      f"time {row['time_pct']:+7.4f}%  "
                      f"energy {row['energy_pct']:+8.3f}%")
        print(f"  total      time {tot['time_pct']:+7.4f}% "
              f"(budget {100*TAU:+.2f}%)  energy {tot['energy_pct']:+8.3f}%"
              f"  switches={tot['n_switches']}")
    return out


def smoke(check: bool = True, tolerance: float = 0.10,
          confirm_retries: int = 2) -> int:
    """Toy-scale throughput run; non-zero exit on >tolerance regression
    against the checked-in ``BENCH_serve.json`` (``make bench-smoke``).

    The gate passes if EITHER absolute tokens/sec clears the floor OR the
    *normalized* engine efficiency does (tokens/sec over the same
    process's raw jitted chunk-step rate — a 2-core CI box swings its
    absolute wall clock +/-20% between processes, which the normalization
    cancels; a real hot-path regression lowers both measures).  A miss is
    re-confirmed with fresh best-of-5 attempts before failing."""
    out = throughput_section(include_wave=False, passes=5)
    tps = out["continuous"]["tokens_per_s"]
    eff = out["engine_efficiency"]
    print(f"bench-smoke: continuous {tps:.1f} tok/s "
          f"(efficiency {eff:.3f}), paged-2x "
          f"{out['paged_2x_slots']['tokens_per_s']:.1f} tok/s")
    if not check:
        return 0
    if not os.path.exists(BENCH_FILE):
        print(f"bench-smoke: no {os.path.basename(BENCH_FILE)} baseline; "
              f"run `python -m benchmarks.serve_continuous` first")
        return 1
    with open(BENCH_FILE) as f:
        base = json.load(f)
    if "tokens_per_s" not in base or "engine_efficiency" not in base:
        print("bench-smoke: baseline lacks tokens_per_s/engine_efficiency;"
              " refresh it with `python -m benchmarks.serve_continuous`")
        return 1
    floor = base["tokens_per_s"] * (1.0 - tolerance)
    eff_floor = base["engine_efficiency"] * (1.0 - tolerance)

    def ok():
        return tps >= floor or eff >= eff_floor

    for attempt in range(confirm_retries):
        if ok():
            break
        print(f"bench-smoke: {tps:.1f} tok/s < floor {floor:.1f} and "
              f"efficiency {eff:.3f} < {eff_floor:.3f}; re-confirming "
              f"({attempt + 1}/{confirm_retries})")
        retry = throughput_section(include_wave=False, passes=5)
        tps = max(tps, retry["continuous"]["tokens_per_s"])
        eff = max(eff, retry["engine_efficiency"])
    verdict = "OK" if ok() else "REGRESSION"
    print(f"bench-smoke: best {tps:.1f} tok/s (floor {floor:.1f}), "
          f"efficiency {eff:.3f} (floor {eff_floor:.3f}, "
          f"{tolerance:.0%} tolerance) -> {verdict}")
    return 0 if ok() else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser(prog="benchmarks.serve_continuous")
    ap.add_argument("--smoke", action="store_true",
                    help="throughput-only toy run (skips DVFS planning)")
    ap.add_argument("--check", action="store_true",
                    help="with --smoke: fail on >10%% tokens/sec "
                         "regression vs BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(check=args.check))
    main()
