"""Continuous batching vs wave batching: throughput, tail latency, energy.

Two claims, measured:

1. **Scheduling** — on a skewed generation-length workload (a straggler in
   every wave), the continuous engine keeps every slot busy while the wave
   engine idles short requests behind the wave straggler.  Measured as
   real wall-clock tokens/sec and per-request completion "latency" (decode
   steps until a request finishes) on a CPU smoke model.
2. **DVFS** — the engine replays an offline
   :class:`~repro.core.phase_plan.PhasePlanBundle` (prefill + per-bucket
   decode plans, planned for the full-size arch on the TPU-v5e-like chip)
   through ``PhaseExecutor``, reporting executed energy vs the auto
   governor at <= the policy's time budget, with per-phase switch counts.

Run:  PYTHONPATH=src python -m benchmarks.serve_continuous
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

ARCH = "llama3.2-1b"
SLOTS = 4
MAX_SEQ = 96
TAU = 0.005
N_REQUESTS = 16


def _requests(vocab: int):
    """Skewed mix: mostly short generations, a 6x straggler every 4th
    request (the wave scheduler's worst case)."""
    import jax  # noqa: F401  (repro.serve pulls jax; keep import local)
    from repro.serve import Request
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(N_REQUESTS):
        plen = 8 if i % 2 == 0 else 12
        new = 48 if i % 4 == 1 else int(rng.integers(4, 10))
        reqs.append(Request(uid=i,
                            prompt=rng.integers(0, vocab, plen),
                            max_new_tokens=new))
    return reqs


def _drive(eng, vocab) -> Dict:
    """Warm-up pass (compiles), reset, then a timed steady-state pass."""
    eng.generate(_requests(vocab))                    # warm-up
    eng.reset()
    reqs = _requests(vocab)
    t0 = time.perf_counter()
    eng.generate(reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in reqs)
    lat = np.array([r.finished_step for r in reqs], dtype=float)
    return {"wall_s": dt, "tokens": tokens,
            "tokens_per_s": tokens / dt,
            "decode_steps": eng.n_decode_steps,
            "latency_steps_p50": float(np.percentile(lat, 50)),
            "latency_steps_p95": float(np.percentile(lat, 95))}


def main(verbose: bool = True) -> Dict:
    import jax
    from repro.configs import REGISTRY, smoke_config
    from repro.configs.base import ShapeConfig
    from repro.core import WastePolicy, get_chip, plan_phase_bundle
    from repro.models import build_model
    from repro.runtime import PhaseExecutor
    from repro.serve import ServeEngine, WaveEngine
    from .common import save_artifact

    cfg = dataclasses.replace(smoke_config(REGISTRY[ARCH]),
                              compute_dtype="float32")
    model = build_model(cfg, block_k=16)
    params = model.init(jax.random.PRNGKey(0))

    # --- 1. scheduling: wall-clock tokens/sec, skewed workload ----------
    wave = _drive(WaveEngine(model, params, batch_slots=SLOTS,
                             max_seq=MAX_SEQ), cfg.vocab_size)
    cont = _drive(ServeEngine(model, params, batch_slots=SLOTS,
                              max_seq=MAX_SEQ), cfg.vocab_size)
    speedup = cont["tokens_per_s"] / wave["tokens_per_s"]

    # --- 2. DVFS: plan the full-size arch, replay through the engine ----
    full = REGISTRY[ARCH]
    chip = get_chip("tpu-v5e")
    pre = ShapeConfig(name="serve_prefill", seq_len=512, global_batch=1,
                      kind="prefill")
    dec = ShapeConfig(name="serve_decode", seq_len=512, global_batch=SLOTS,
                      kind="decode")
    bundle = plan_phase_bundle(full, chip, n_slots=SLOTS,
                               prefill_shape=pre, decode_shape=dec,
                               policy=WastePolicy(TAU), n_reps=10)
    ex = PhaseExecutor(bundle, chip)
    eng = ServeEngine(model, params, batch_slots=SLOTS, max_seq=MAX_SEQ,
                      executor=ex)
    eng.generate(_requests(cfg.vocab_size))
    energy = eng.energy_summary()

    out = {
        "arch": ARCH, "slots": SLOTS, "n_requests": N_REQUESTS,
        "wave": wave, "continuous": cont,
        "throughput_speedup": speedup,
        "tau": TAU,
        "energy": energy,
    }
    save_artifact("serve_continuous", out)

    if verbose:
        print(f"skewed workload, {N_REQUESTS} requests, {SLOTS} slots:")
        for tag, r in (("wave", wave), ("continuous", cont)):
            print(f"  {tag:10s}: {r['tokens']} tok in {r['wall_s']:.2f}s"
                  f" ({r['tokens_per_s']:.1f} tok/s,"
                  f" {r['decode_steps']} decode steps,"
                  f" p50/p95 latency {r['latency_steps_p50']:.0f}/"
                  f"{r['latency_steps_p95']:.0f} steps)")
        print(f"  speedup    : {speedup:.2f}x tokens/sec")
        tot = energy["totals"]
        print(f"DVFS replay ({full.name} on {chip.name}, tau={TAU}):")
        for name, row in energy["phases"].items():
            if row["steps"]:
                print(f"  {name:10s} steps={row['steps']:3d} "
                      f"switches={row['n_switches']:3d} "
                      f"time {row['time_pct']:+7.4f}%  "
                      f"energy {row['energy_pct']:+8.3f}%")
        print(f"  total      time {tot['time_pct']:+7.4f}% "
              f"(budget {100*TAU:+.2f}%)  energy {tot['energy_pct']:+8.3f}%"
              f"  switches={tot['n_switches']}")
    return out


if __name__ == "__main__":
    main()
