"""Shared benchmark plumbing: the GPT-3-xl case-study campaign (paper §4)."""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from repro.configs import get_config, get_shape
from repro.core import (Campaign, WastePolicy, build_workload, get_chip,
                        global_plan, local_plan)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench")


def save_artifact(name: str, payload: Dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def gpt3xl_campaign(chip_name: str = "rtx3080ti", seed: int = 0,
                    n_reps: int = 5, batch: Optional[int] = None,
                    tp: int = 1, sp: bool = False):
    """The paper's measurement campaign: GPT-3-xl, seq 1024, batch 40."""
    cfg = get_config("gpt3-xl")
    shape = get_shape("paper_gpt3xl")
    kernels = build_workload(cfg, shape, tp=tp, sp=sp,
                             batch_override=batch)
    chip = get_chip(chip_name)
    camp = Campaign(chip, seed=seed, n_reps=n_reps)
    table = camp.run(kernels)
    return camp, table


def fmt_pct(x: float) -> str:
    return f"{x:+.2f}%"
