"""Shared benchmark plumbing: the GPT-3-xl case-study campaign (paper §4)
and the governor-registry planning entry all DVFS benchmarks go through."""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from repro.configs import get_config, get_shape
from repro.core import Campaign, WastePolicy, build_workload, get_chip
from repro.dvfs import governor

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench")


def save_artifact(name: str, payload: Dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def gpt3xl_campaign(chip_name: str = "rtx3080ti", seed: int = 0,
                    n_reps: int = 5, batch: Optional[int] = None,
                    tp: int = 1, sp: bool = False):
    """The paper's measurement campaign: GPT-3-xl, seq 1024, batch 40."""
    cfg = get_config("gpt3-xl")
    shape = get_shape("paper_gpt3xl")
    kernels = build_workload(cfg, shape, tp=tp, sp=sp,
                             batch_override=batch)
    chip = get_chip(chip_name)
    camp = Campaign(chip, seed=seed, n_reps=n_reps)
    table = camp.run(kernels)
    return camp, table


def solve(table, gov: str = "kernel-static", tau: float = 0.0, **gov_kw):
    """Plan one measurement table through the ``repro.dvfs`` governor
    registry (the facade every DVFS benchmark routes planning through).

    Returns the governor's legacy per-kernel :class:`~repro.core.Plan` —
    the same object the named planner functions produce, so benchmark
    numbers are unchanged; only the entry point is unified.
    """
    return governor(gov, policy=WastePolicy(tau), **gov_kw).solve(table)


def fmt_pct(x: float) -> str:
    return f"{x:+.2f}%"
