"""Beyond-paper: measurement-efficient global search vs the paper's
exhaustive 3-GPU-day campaign (§4 'Search').

Compares plan quality (true energy saving at the strict/relaxed budget)
against measurement cost in repetition-units.
"""
from __future__ import annotations

import numpy as np

from repro.core import (Campaign, WastePolicy, build_workload, get_chip,
                        global_plan)
from repro.core.search import evaluate_against_truth, search_plan
from repro.configs import get_config, get_shape
from .common import save_artifact


def main(verbose: bool = True):
    cfg = get_config("gpt3-xl")
    shape = get_shape("paper_gpt3xl")
    kernels = build_workload(cfg, shape)
    chip = get_chip("rtx3080ti")

    # exhaustive reference (5 reps everywhere)
    camp = Campaign(chip, seed=0, n_reps=5)
    table = camp.run(kernels)
    exh = global_plan(table, WastePolicy(0.0))
    exh_t, exh_e = evaluate_against_truth(chip, kernels, exh)
    exh_cost = len(kernels) * len(table.pairs) * 5

    rows = [{"method": "exhaustive(5 reps)", "measurements": exh_cost,
             "cost_frac": 1.0, "true_time_pct": exh_t,
             "true_energy_pct": exh_e}]
    for rounds, base in ((2, 1), (3, 1), (3, 2)):
        plan, rep = search_plan(chip, kernels, WastePolicy(0.0),
                                rounds=rounds, base_reps=base, seed=1)
        t, e = evaluate_against_truth(chip, kernels, plan)
        rows.append({"method": f"pruned-halving r{rounds}b{base}",
                     "measurements": rep.measurements,
                     "cost_frac": rep.measurements / exh_cost,
                     "true_time_pct": t, "true_energy_pct": e,
                     "cells_swept_frac": rep.cells_swept / rep.cells_total})
    if verbose:
        for r in rows:
            print(f"[search_cost] {r['method']:24s} "
                  f"meas={r['measurements']:6d} "
                  f"({100*r['cost_frac']:5.1f}% of exhaustive)  "
                  f"true: t={r['true_time_pct']:+6.2f}% "
                  f"e={r['true_energy_pct']:+7.2f}%")
    save_artifact("search_cost", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    main()
