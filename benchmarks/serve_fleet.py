"""Fleet-tier serving: trace-driven routing, cluster power capping, and
heterogeneous replica mixes — all over executed kernel-level DVFS plans.

Three claims, measured on one seeded 200-request trace family replayed
across >= 3 replicas in modeled time (real scheduler/governor/executor
code paths, analytic chip clocks — the same accounting substrate as
every other benchmark):

1. **Routing** — under peak load with heavy-tailed generation lengths,
   the energy/SLO-aware router (scoring predicted marginal energy off
   each replica's active DvfsPlan, backing off on predicted TTFT risk)
   beats round-robin on joules-per-token at equal-or-better p99 TTFT;
   blind spreading strands two replicas idle behind one backlogged
   straggler-grinder, losing both metrics at once.
2. **Power cap** — a `FleetGovernor` holds a cluster cap 5% under the
   fleet's natural draw by solving one shared Lagrangian budget across
   replicas (the decode-joint machinery, promoted one tier) and pushing
   revised plans through each replica's online re-plan path.  Because
   per-kernel frontiers are steep near the operating point, the capped
   fleet tracks the cap within 2% while slowing the workload's makespan
   by well under 1% — the composition the McDonald et al. fleet-capping
   tradeoff says costs real latency when done with blunt clocks.
3. **Heterogeneity** — the same trace on a 2x rtx3080ti + 1x a4000 mix
   (the a4000's serve plan *transferred* from the 3080ti's via
   cross-chip relative-frequency snap — re-measured on the target to
   repair and account the choices, but never re-planned)
   completes with lower total energy than the homogeneous 3x rtx3080ti
   baseline (Wilkins et al.'s hybrid-cluster result, here with
   kernel-level plans on every replica).
4. **Disaggregation** — a phase-split fleet (6 prefill replicas whose
   plans keep only the compute-tilted prefill segment + 2 deep-slotted
   decode replicas, KV page blocks migrated over a modeled link and
   charged into the books) beats *every* homogeneous unified shape in a
   slot-count sweep on joules-per-token at equal-or-better p99 TTFT on
   a bursty trace: the decode pool packs to its cheapest (deepest)
   bucket without holding prefill admission hostage, while unified
   fleets must pick one slot depth for both phases.
5. **Fault tolerance** — under a seeded fault storm (a prefill and a
   decode replica crash mid-flight, a thermal cap clamps a replica's
   frequency grid, the migration link drops and degrades transfers, a
   driver window rejects set-frequency calls), the recovering fleet
   completes 100% of the trace with bounded p99 TTFT inflation and
   single-digit-% J/token overhead vs the fault-free run, while a
   no-recovery baseline strands the crashed replicas' in-flight
   requests.

Writes the repo-root ``BENCH_fleet.json`` anchor; ``make bench-smoke``
re-runs the router section and fails on a >10% joules-per-token
regression or any lost claim.

Run:  PYTHONPATH=src python -m benchmarks.serve_fleet
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

ARCH = "llama3.2-1b"
N_REQUESTS = 200
SEED = 0
CAP_FRACTION = 0.95

BENCH_FILE = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_fleet.json")

#: the energy/SLO router operating point used across sections (TTFT
#: target chosen per chip speed: tpu prefill ~17ms, gpu prefill ~42-75ms)
TPU_ROUTER = dict(slo_ttft_s=0.08, slo_weight=60.0, slack=0.3)
GPU_ROUTER = dict(slo_ttft_s=0.3, slo_weight=60.0, slack=0.3)
#: disagg section: a looser TTFT target with a wide slack band lets the
#: router pack for energy on both sides of the comparison (the regime
#: where slot-depth economics, not SLO panic, decide placement)
DISAGG_ROUTER = dict(slo_ttft_s=0.10, slo_weight=30.0, slack=0.4)
DISAGG_RATE = 200.0
DISAGG_REQUESTS = 300
#: homogeneous slot depths swept for the "best unified" baseline
DISAGG_UNIFIED_SLOTS = (4, 8, 16)


def _peak_trace(n_requests: int = N_REQUESTS, rate: float = 80.0,
                process: str = "poisson"):
    """Peak-load trace with heavy-tailed generations (64-token straggler
    every 3rd request — the regime where routing policy matters)."""
    from repro.fleet import generate_trace
    return generate_trace(process, n_requests=n_requests, rate_rps=rate,
                          seed=SEED, straggler_tokens=64,
                          straggler_every=3)


def _fleet(specs, router_name, rkw=None, **kw):
    from repro.configs import REGISTRY
    from repro.fleet import build_fleet, router
    cfg = REGISTRY[ARCH]
    r = router(router_name, **rkw) if rkw else router_name
    return build_fleet(specs, cfg, router=r, n_reps=3, seed=SEED, **kw)


def _row(rep: Dict) -> Dict:
    return {"joules_per_token": rep["joules_per_token"],
            "energy_j": rep["energy_j"],
            "idle_energy_j": rep["idle_energy_j"],
            "ttft_p50_s": rep["ttft_p50_s"],
            "ttft_p99_s": rep["ttft_p99_s"],
            "tpot_p99_s": rep["tpot_p99_s"],
            "makespan_s": rep["makespan_s"],
            "n_completed": rep["n_completed"]}


def router_section(n_requests: int = N_REQUESTS) -> Dict:
    """Claim 1: round-robin vs least-queue vs energy-slo on 3 replicas."""
    from repro.fleet import ReplicaSpec
    trace = _peak_trace(n_requests)
    specs = [ReplicaSpec(chip="tpu-v5e")] * 3
    out: Dict = {"trace": trace.summary(), "routers": {}}
    for name, rkw in (("round-robin", None), ("least-queue", None),
                      ("energy-slo", TPU_ROUTER)):
        rep = _fleet(specs, name, rkw).serve(trace)
        out["routers"][name] = _row(rep)
    rr = out["routers"]["round-robin"]
    es = out["routers"]["energy-slo"]
    out["energy_slo_beats_rr"] = (
        es["joules_per_token"] < rr["joules_per_token"]
        and es["ttft_p99_s"] <= rr["ttft_p99_s"])
    out["j_per_tok_vs_rr_pct"] = 100.0 * (
        es["joules_per_token"] / rr["joules_per_token"] - 1.0)
    return out


def powercap_section(n_requests: int = N_REQUESTS) -> Dict:
    """Claim 2: shared-Lagrangian cap at 95% of the natural draw.

    Round-robin placements are independent of the plans, so capped and
    uncapped runs serve bit-identical schedules — the makespan delta
    isolates the frequency cost of the cap, not routing dynamics.  The
    saturating no-straggler trace keeps every window loaded."""
    from repro.fleet import FleetGovernor, ReplicaSpec, generate_trace
    trace = generate_trace("poisson", n_requests=n_requests,
                           rate_rps=130.0, seed=SEED,
                           mean_new_tokens=12, straggler_every=0)
    specs = [ReplicaSpec(chip="tpu-v5e")] * 3

    # matched window cadence: the capped run is compared against the
    # baseline's loaded-power statistic, so both use 0.25 s windows
    base = _fleet(specs, "round-robin",
                  tick_interval_s=0.25).serve(trace)
    cap_w = CAP_FRACTION * base["power"]["mean_loaded_w"]
    gov = FleetGovernor(cap_w, interval_s=0.25)
    capped = _fleet(specs, "round-robin",
                    fleet_governor=gov).serve(trace)

    slowdown = capped["makespan_s"] / base["makespan_s"] - 1.0
    return {
        "uncapped": dict(_row(base), power=base["power"]),
        "cap_w": cap_w, "cap_fraction": CAP_FRACTION,
        "capped": dict(_row(capped), power=capped["power"]),
        "governor": capped["fleet_governor"],
        "tracking_err_frac":
            capped["power"]["loaded_tracking_err_frac"],
        "slowdown_frac": slowdown,
        "cap_held_2pct":
            capped["power"]["loaded_tracking_err_frac"] <= 0.02,
        "slowdown_under_1pct": slowdown < 0.01,
    }


def hetero_section(n_requests: int = N_REQUESTS) -> Dict:
    """Claim 3: 2x rtx3080ti + 1x a4000 (transferred plan) vs 3x
    rtx3080ti on a diurnal trace with idle auto-parking."""
    from repro.fleet import ReplicaSpec, generate_trace
    trace = generate_trace("diurnal", n_requests=n_requests,
                           rate_rps=25.0, seed=SEED,
                           straggler_tokens=64, straggler_every=3)
    homo_specs = [ReplicaSpec(chip="rtx3080ti")] * 3
    het_specs = [ReplicaSpec(chip="rtx3080ti")] * 2 \
        + [ReplicaSpec(chip="a4000")]
    homo = _fleet(homo_specs, "energy-slo", GPU_ROUTER,
                  autopark_idle_s=0.3).serve(trace)
    het = _fleet(het_specs, "energy-slo", GPU_ROUTER,
                 autopark_idle_s=0.3,
                 transfer_from="rtx3080ti").serve(trace)
    return {
        "trace": trace.summary(),
        "homogeneous_3x3080ti": _row(homo),
        "heterogeneous_2x3080ti_1xa4000": _row(het),
        "hetero_energy_vs_homo_pct":
            100.0 * (het["energy_j"] / homo["energy_j"] - 1.0),
        "hetero_wins": (het["energy_j"] < homo["energy_j"]
                        and het["n_completed"] == n_requests),
    }


def disagg_section(n_requests: int = DISAGG_REQUESTS) -> Dict:
    """Claim 4: 6 prefill + 2 deep-slotted decode replicas vs the best
    homogeneous unified 8-replica fleet over a slot-count sweep.

    Same chip everywhere (tpu-v5e), same bursty trace, same router and
    auto-park policy — the only degree of freedom is how the 8 chips
    split the two serving phases.  Unified shapes trade TTFT against
    decode economics through one shared slot depth: shallow slots
    admit bursts slowly (slot-release waits), deep slots decode cheap
    but drag every request's TPOT through huge decode batches.  The
    disaggregated fleet holds both ends: prefill replicas turn slots
    over at prefill cadence (pages migrate out immediately), decode
    replicas pack migrated requests into their deepest (cheapest
    J/token) buckets, and the migration link's time + energy is charged
    into the same books the claim is scored on.
    """
    from repro.fleet import parse_replica_specs, generate_trace
    trace = generate_trace("bursty", n_requests=n_requests,
                           rate_rps=DISAGG_RATE, seed=SEED,
                           straggler_tokens=64, straggler_every=3)
    out: Dict = {"trace": trace.summary(), "unified": {}}
    for n_slots in DISAGG_UNIFIED_SLOTS:
        specs = parse_replica_specs(f"8xtpu-v5e:{n_slots}")
        rep = _fleet(specs, "energy-slo", DISAGG_ROUTER,
                     autopark_idle_s=0.5).serve(trace)
        out["unified"][str(n_slots)] = _row(rep)
    specs = parse_replica_specs(
        "6xtpu-v5e:4@prefill,2xtpu-v5e:16@decode")
    rep = _fleet(specs, "energy-slo", DISAGG_ROUTER,
                 autopark_idle_s=0.5).serve(trace)
    out["disagg"] = dict(
        _row(rep), n_migrations=rep["n_migrations"],
        migration_bytes=rep["migration_bytes"],
        migration_energy_j=rep["migration_energy_j"],
        migration_s=rep["migration_s"])
    # best homogeneous shape = lowest J/token that finished the trace
    done = {k: v for k, v in out["unified"].items()
            if v["n_completed"] == n_requests}
    best_key = min(done, key=lambda k: done[k]["joules_per_token"])
    best = done[best_key]
    dis = out["disagg"]
    out["best_unified_slots"] = int(best_key)
    out["best_unified"] = best
    out["disagg_vs_unified_pct"] = 100.0 * (
        dis["joules_per_token"] / best["joules_per_token"] - 1.0)
    out["disagg_wins"] = (
        dis["joules_per_token"] < best["joules_per_token"]
        and dis["ttft_p99_s"] <= best["ttft_p99_s"]
        and dis["n_completed"] == n_requests)
    return out


FAULT_SPECS = "3xtpu-v5e:4@prefill,2xtpu-v5e:8@decode"
FAULT_RATE = 150.0
FAULT_REQUESTS = 200


def fault_section(n_requests: int = FAULT_REQUESTS) -> Dict:
    """Claim 5 (docs claim 14): fault-tolerant serving.  The seeded
    ``storm`` schedule (one prefill + one decode crash, a thermal clock
    cap, a flaky migration link, a driver set-frequency fault window)
    replays against a disaggregated fleet three ways: fault-free,
    faulted with recovery, and faulted with recovery disabled.  The
    recovering fleet must complete 100% of the trace with bounded p99
    TTFT inflation and single-digit-% J/token overhead (it pays for
    re-run prefills and burned link retries inside the same books),
    while the no-recovery baseline strands the crashed replicas'
    in-flight requests."""
    from repro.fleet import generate_faults, generate_trace, \
        parse_replica_specs
    trace = generate_trace("bursty", n_requests=n_requests,
                           rate_rps=FAULT_RATE, seed=SEED,
                           straggler_tokens=64, straggler_every=3)
    specs = parse_replica_specs(FAULT_SPECS)
    kw = dict(rkw=DISAGG_ROUTER, controller="rate-limited")
    clean_fleet = _fleet(specs, "energy-slo", **kw)
    names = [r.name for r in clean_fleet.replicas]
    storm = generate_faults("storm", seed=SEED, replicas=names,
                            duration_s=trace.duration_s)
    clean = clean_fleet.serve(trace)
    faulted = _fleet(specs, "energy-slo", faults=storm, **kw).serve(trace)
    baseline = _fleet(specs, "energy-slo", faults=storm, recover=False,
                      **kw).serve(trace)
    out: Dict = {
        "trace": trace.summary(), "schedule": storm.summary(),
        "replicas": names,
        "fault_free": _row(clean),
        "recovering": dict(_row(faulted), n_stranded=faulted["n_stranded"],
                           recovery=faulted["recovery"]),
        "no_recovery": dict(_row(baseline),
                            n_stranded=baseline["n_stranded"],
                            recovery=baseline["recovery"]),
    }
    out["completion_frac"] = faulted["n_completed"] / n_requests
    out["baseline_stranded"] = baseline["n_stranded"]
    out["j_per_tok_overhead_pct"] = 100.0 * (
        faulted["joules_per_token"] / clean["joules_per_token"] - 1.0)
    out["ttft_p99_inflation_pct"] = 100.0 * (
        faulted["ttft_p99_s"] / clean["ttft_p99_s"] - 1.0)
    out["fault_tolerant"] = (
        out["completion_frac"] == 1.0
        and out["baseline_stranded"] >= 1
        and out["j_per_tok_overhead_pct"] < 10.0
        and out["ttft_p99_inflation_pct"] < 50.0)
    return out


def _write_bench_file(payload: Dict) -> None:
    with open(BENCH_FILE, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")


def _print_disagg(dis) -> None:
    print(f"fleet disaggregation (bursty@{DISAGG_RATE:.0f} rps, "
          f"{DISAGG_REQUESTS} requests, 8x tpu-v5e):")
    for k in sorted(dis["unified"], key=int):
        row = dis["unified"][k]
        print(f"  unified 8x:{k:>2s} : {row['joules_per_token']:.4f} "
              f"J/tok, TTFT p99 {row['ttft_p99_s']*1e3:.0f} ms, "
              f"TPOT p99 {row['tpot_p99_s']*1e3:.1f} ms")
    d = dis["disagg"]
    print(f"  disagg 6pre+2dec: {d['joules_per_token']:.4f} J/tok, "
          f"TTFT p99 {d['ttft_p99_s']*1e3:.0f} ms, TPOT p99 "
          f"{d['tpot_p99_s']*1e3:.1f} ms "
          f"({d['n_migrations']} migrations, "
          f"{d['migration_bytes']/1e6:.1f} MB, "
          f"{d['migration_energy_j']:.2f} J charged)")
    print(f"  vs best unified (8x:{dis['best_unified_slots']}): "
          f"{dis['disagg_vs_unified_pct']:+.1f}% J/tok at <= p99 TTFT "
          f"-> {'OK' if dis['disagg_wins'] else 'LOST'}")


def _print_faults(fl) -> None:
    print(f"fleet fault tolerance (storm on {FAULT_SPECS}, "
          f"bursty@{FAULT_RATE:.0f} rps, {FAULT_REQUESTS} requests):")
    rec = fl["recovering"]["recovery"]
    print(f"  fault-free  : "
          f"{fl['fault_free']['joules_per_token']:.4f} J/tok, TTFT p99 "
          f"{fl['fault_free']['ttft_p99_s']*1e3:.0f} ms")
    print(f"  recovering  : "
          f"{fl['recovering']['joules_per_token']:.4f} J/tok "
          f"({fl['j_per_tok_overhead_pct']:+.1f}%), TTFT p99 "
          f"{fl['recovering']['ttft_p99_s']*1e3:.0f} ms "
          f"({fl['ttft_p99_inflation_pct']:+.1f}%), "
          f"{fl['completion_frac']:.0%} complete "
          f"[{rec['n_crashes']} crashes, {rec['n_redispatched']} "
          f"re-dispatched, {rec['n_reprefills']} re-prefills, "
          f"{rec['n_link_retries']} link retries]")
    print(f"  no-recovery : {fl['baseline_stranded']} stranded of "
          f"{FAULT_REQUESTS}")
    print(f"  100% completion + bounded overhead "
          f"-> {'OK' if fl['fault_tolerant'] else 'LOST'}")


def _print_sections(routers, cap, het) -> None:
    print(f"fleet routing ({N_REQUESTS} requests, 3x tpu-v5e, "
          f"peak poisson + stragglers):")
    for name, row in routers["routers"].items():
        print(f"  {name:12s}: {row['joules_per_token']:.4f} J/tok, "
              f"TTFT p50/p99 {row['ttft_p50_s']*1e3:.0f}/"
              f"{row['ttft_p99_s']*1e3:.0f} ms, "
              f"makespan {row['makespan_s']:.2f}s")
    print(f"  energy-slo vs round-robin: "
          f"{routers['j_per_tok_vs_rr_pct']:+.1f}% J/tok at <= p99 "
          f"-> {'OK' if routers['energy_slo_beats_rr'] else 'LOST'}")
    print(f"fleet power cap ({CAP_FRACTION:.0%} of natural draw = "
          f"{cap['cap_w']:.0f} W):")
    print(f"  tracking error {cap['tracking_err_frac']*100:.2f}% "
          f"(held within 2%: {cap['cap_held_2pct']}), makespan "
          f"slowdown {cap['slowdown_frac']*100:+.2f}% "
          f"(<1%: {cap['slowdown_under_1pct']}), "
          f"{cap['governor']['n_replans']} online re-plans")
    print("fleet heterogeneity (diurnal trace, auto-park, "
          "a4000 plan transferred from rtx3080ti):")
    ho = het["homogeneous_3x3080ti"]
    he = het["heterogeneous_2x3080ti_1xa4000"]
    print(f"  homo 3x3080ti : {ho['energy_j']:.0f} J "
          f"({ho['joules_per_token']:.3f} J/tok)")
    print(f"  het 2+1       : {he['energy_j']:.0f} J "
          f"({he['joules_per_token']:.3f} J/tok), "
          f"{het['hetero_energy_vs_homo_pct']:+.1f}% energy "
          f"-> {'OK' if het['hetero_wins'] else 'LOST'}")


def main(verbose: bool = True) -> Dict:
    from .common import save_artifact

    routers = router_section()
    cap = powercap_section()
    het = hetero_section()
    dis = disagg_section()
    fl = fault_section()
    out = {"arch": ARCH, "n_requests": N_REQUESTS,
           "router": routers, "powercap": cap, "hetero": het,
           "disagg": dis, "faults": fl}
    save_artifact("serve_fleet", out)

    es = routers["routers"]["energy-slo"]
    _write_bench_file({
        "arch": ARCH, "n_requests": N_REQUESTS, "n_replicas": 3,
        "energy_slo_j_per_tok": es["joules_per_token"],
        "energy_slo_ttft_p99_s": es["ttft_p99_s"],
        "j_per_tok_vs_rr_pct": routers["j_per_tok_vs_rr_pct"],
        "cap_tracking_err_frac": cap["tracking_err_frac"],
        "cap_slowdown_frac": cap["slowdown_frac"],
        "hetero_energy_vs_homo_pct": het["hetero_energy_vs_homo_pct"],
        "disagg_j_per_tok": dis["disagg"]["joules_per_token"],
        "disagg_ttft_p99_s": dis["disagg"]["ttft_p99_s"],
        "disagg_vs_unified_pct": dis["disagg_vs_unified_pct"],
        "disagg_n_migrations": dis["disagg"]["n_migrations"],
        "fault_completion_frac": fl["completion_frac"],
        "fault_j_per_tok":
            fl["recovering"]["joules_per_token"],
        "fault_overhead_pct": fl["j_per_tok_overhead_pct"],
        "fault_ttft_p99_inflation_pct": fl["ttft_p99_inflation_pct"],
        "fault_baseline_stranded": fl["baseline_stranded"],
    })
    if verbose:
        _print_sections(routers, cap, het)
        _print_disagg(dis)
        _print_faults(fl)
    return out


def smoke(check: bool = True, tolerance: float = 0.10) -> int:
    """Re-run the five fleet claims at benchmark scale (already toy);
    non-zero exit on a lost claim or a >tolerance joules-per-token
    regression vs the checked-in ``BENCH_fleet.json`` (the breach
    message names the offending anchor)."""
    routers = router_section()
    cap = powercap_section()
    het = hetero_section()
    dis = disagg_section()
    fl = fault_section()
    es = routers["routers"]["energy-slo"]
    print(f"bench-smoke(fleet): energy-slo "
          f"{es['joules_per_token']:.4f} J/tok "
          f"({routers['j_per_tok_vs_rr_pct']:+.1f}% vs rr), cap err "
          f"{cap['tracking_err_frac']*100:.2f}%, hetero "
          f"{het['hetero_energy_vs_homo_pct']:+.1f}%, disagg "
          f"{dis['disagg_vs_unified_pct']:+.1f}%, faults "
          f"{fl['completion_frac']:.0%} complete "
          f"({fl['j_per_tok_overhead_pct']:+.1f}% J/tok, "
          f"baseline strands {fl['baseline_stranded']})")
    claims_ok = (routers["energy_slo_beats_rr"]
                 and cap["cap_held_2pct"] and cap["slowdown_under_1pct"]
                 and het["hetero_wins"] and dis["disagg_wins"]
                 and fl["fault_tolerant"])
    if not claims_ok:
        print("bench-smoke(fleet): LOST CLAIM "
              f"(router={routers['energy_slo_beats_rr']}, "
              f"cap={cap['cap_held_2pct']}/{cap['slowdown_under_1pct']},"
              f" hetero={het['hetero_wins']}, "
              f"disagg={dis['disagg_wins']}, "
              f"faults={fl['fault_tolerant']})")
        return 1
    if not check:
        return 0
    if not os.path.exists(BENCH_FILE):
        print(f"bench-smoke(fleet): no {os.path.basename(BENCH_FILE)} "
              f"baseline; run `python -m benchmarks.serve_fleet` first")
        return 1
    with open(BENCH_FILE) as f:
        base = json.load(f)
    #: per-anchor J/token ceilings; a breach names the offending anchor
    gates = (
        ("energy_slo_j_per_tok", es["joules_per_token"]),
        ("disagg_j_per_tok", dis["disagg"]["joules_per_token"]),
        ("fault_j_per_tok", fl["recovering"]["joules_per_token"]),
    )
    for anchor, measured in gates:
        if anchor not in base:
            continue
        ceil = base[anchor] * (1.0 + tolerance)
        ok = measured <= ceil
        print(f"bench-smoke(fleet): {anchor} {measured:.4f} J/tok vs "
              f"ceiling {ceil:.4f} ({tolerance:.0%} over "
              f"{base[anchor]:.4f}) -> "
              f"{'OK' if ok else 'REGRESSION'}")
        if not ok:
            return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(prog="benchmarks.serve_fleet")
    ap.add_argument("--smoke", action="store_true",
                    help="re-run the five claims and exit non-zero on "
                         "a lost claim")
    ap.add_argument("--check", action="store_true",
                    help="with --smoke: fail on >10%% joules-per-token "
                         "regression vs BENCH_fleet.json")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke(check=args.check))
    main()
