"""Paper Fig. 8 / §8: tensor (+sequence) parallelism — apply the TP=1
discovered clocks to TP in {1,2,4,8,16} shards (communication excluded,
as in the paper's Megatron-style llm.c extension)."""
from __future__ import annotations

import numpy as np

from .common import gpt3xl_campaign, save_artifact, solve

DEGREES = (1, 2, 4, 8, 16)


def main(verbose: bool = True):
    camp0, table0 = gpt3xl_campaign(tp=1, sp=True)
    plan = solve(table0, "kernel-static")
    rows = []
    for d in DEGREES:
        camp, table = gpt3xl_campaign(tp=d, sp=True, seed=200 + d)
        t, e = table.totals(plan.choice)
        tb, eb = table.baseline_totals()
        rows.append({"tp": d,
                     "time_pct": 100 * (t / tb - 1),
                     "energy_pct": 100 * (e / eb - 1),
                     "abs_time_s": t, "abs_energy_j": e})
        if verbose:
            r = rows[-1]
            print(f"[tensor_parallel] tp={d:2d}: t={r['time_pct']:+6.2f}% "
                  f"e={r['energy_pct']:+7.2f}%")
    spread_t = max(r["time_pct"] for r in rows) - \
        min(r["time_pct"] for r in rows)
    spread_e = max(r["energy_pct"] for r in rows) - \
        min(r["energy_pct"] for r in rows)
    out = {"rows": rows, "time_spread_pp": spread_t,
           "energy_spread_pp": spread_e}
    if verbose:
        print(f"[tensor_parallel] transfer spread: {spread_t:.2f} pp time, "
              f"{spread_e:.2f} pp energy (paper: <=2 pp / <=6 pp)")
    save_artifact("tensor_parallel", out)
    return out


if __name__ == "__main__":
    main()
